//! End-to-end simulator throughput per discipline (paper §A.1: their
//! Python simulator runs 10k jobs in ~0.5 s; DESIGN.md §Perf targets
//! <5 ms for PS-class policies here) plus per-event scheduler cost at
//! a standing 10k-job population (the §5.2.2 O(log n) vs O(n) numbers;
//! the full population curve lives in the psbs_ops bench).
//!
//! Results land in `BENCH_sched.json`.  Filter with
//! `cargo bench --bench schedulers -- event/,batch/,soa/` for a quick
//! per-event + batching smoke (what scripts/tier1.sh runs — one
//! invocation so the rewritten JSON still carries every gated key).

use psbs::sched;
use psbs::sim::{self, Job, Scheduler};
use psbs::util::bench::{self, Bench};
use psbs::workload::{self, SynthConfig};

#[path = "common.rs"]
mod common;
use common::{preload, probe, TINY};

fn main() {
    let mut b = Bench::new();

    let cfg = SynthConfig::default().with_njobs(10_000);
    let jobs = workload::synthesize(&cfg, 42);
    for policy in sched::ALL_POLICIES {
        // fsp-naive is O(n^2)-ish on 10k jobs; bench it at this size
        // anyway — it IS the comparison the paper's §5.2.2 makes.
        let jobs = jobs.clone();
        b.bench_items(&format!("sim/10k_default/{policy}"), Some(jobs.len() as u64), move || {
            let mut s = sched::by_name(policy).unwrap();
            let r = sim::run(s.as_mut(), &jobs);
            std::hint::black_box(r.events);
        });
    }

    // Scaling: PSBS at increasing n (the O(log n) claim end to end).
    for njobs in [1_000usize, 10_000, 100_000] {
        let cfg = SynthConfig::default().with_njobs(njobs);
        let jobs = workload::synthesize(&cfg, 43);
        b.bench_items(&format!("sim/psbs/n{njobs}"), Some(njobs as u64), move || {
            let mut s = sched::by_name("psbs").unwrap();
            let r = sim::run(s.as_mut(), &jobs);
            std::hint::black_box(r.events);
        });
    }

    // Per-event cost against a standing population of 10k jobs: one
    // tiny-job arrival + completion pair per iteration (methodology as
    // in the psbs_ops bench, which sweeps the population size).
    for policy in ["psbs", "fsp-naive"] {
        let n = 10_000usize;
        let (mut s, mut store) = preload(policy, n);
        let pid = n as u32;
        let mut now = n as f64 * 1e-6;
        let mut done = Vec::with_capacity(1);
        let dt = TINY * 4.0 * (n as f64 + 2.0);
        b.bench(&format!("event/{policy}/n{n}"), move || {
            probe(s.as_mut(), &mut store, now, &Job::exact(pid, now, TINY));
            std::hint::black_box(s.next_event(now));
            done.clear();
            s.advance(now, now + dt, &store, &mut done);
            debug_assert_eq!(done.len(), 1);
            now += dt;
            std::hint::black_box(done.len());
        });
    }

    // The same probe loop, named under `soa/` so the struct-of-arrays
    // store's event cost is tracked as its own key (`soa_event_ns` in
    // `derived`): arrival field reads go through the [`psbs::sim::JobStore`]
    // parallel arrays rather than a materialized `Job`.
    {
        let n = 10_000usize;
        let (mut s, mut store) = preload("psbs", n);
        let pid = n as u32;
        let mut now = n as f64 * 1e-6;
        let mut done = Vec::with_capacity(1);
        let dt = TINY * 4.0 * (n as f64 + 2.0);
        b.bench("soa/event/psbs/n10k", move || {
            probe(s.as_mut(), &mut store, now, &Job::exact(pid, now, TINY));
            std::hint::black_box(s.next_event(now));
            done.clear();
            s.advance(now, now + dt, &store, &mut done);
            debug_assert_eq!(done.len(), 1);
            now += dt;
            std::hint::black_box(done.len());
        });
    }

    // Batched same-instant delivery vs one-by-one: BURST tiny jobs
    // land at one timestamp against the standing population, then the
    // burst is drained to completion.  `grouped` hands the engine-shaped
    // single `on_arrival_batch` call; `onebyone` pays a dyn-dispatched
    // `on_arrival` per job (the pre-batching engine loop).  Both
    // variants share the drain cost, so the derived
    // `batch_event_speedup` (gated in scripts/bench_compare.py) isolates
    // what coalescing saves per burst.
    const BURST: u32 = 64;
    for grouped in [false, true] {
        let n = 10_000usize;
        let (mut s, mut store) = preload("psbs", n);
        let base = n as u32;
        let mut now = n as f64 * 1e-6;
        let mut done = Vec::with_capacity(BURST as usize);
        let label = if grouped { "grouped" } else { "onebyone" };
        b.bench(&format!("batch/{label}/psbs/burst{BURST}"), move || {
            for i in 0..BURST {
                store.upsert(&Job::exact(base + i, now, TINY));
            }
            if grouped {
                s.on_arrival_batch(now, base..base + BURST, &store);
            } else {
                for id in base..base + BURST {
                    s.on_arrival(now, id, &store);
                }
            }
            done.clear();
            while done.len() < BURST as usize {
                let t = s.next_event(now).expect("pending work").max(now);
                s.advance(now, t, &store, &mut done);
                now = t;
            }
            std::hint::black_box(done.len());
        });
    }

    // Workload synthesis itself.
    b.bench_items("workload/synthesize_10k", Some(10_000), || {
        let cfg = SynthConfig::default().with_njobs(10_000);
        std::hint::black_box(workload::synthesize(&cfg, 7).len());
    });

    // Derived keys: `batch_event_speedup` (>= 1 means one coalesced
    // batch call per burst is no slower than per-job dispatch — gated),
    // `soa_event_ns` (absolute SoA event cost, informational).
    let mean_of = |name: &str| b.samples.iter().find(|s| s.name == name).map(|s| s.mean_ns);
    let mut derived: Vec<(String, f64)> = Vec::new();
    if let (Some(one), Some(grp)) = (
        mean_of(&format!("batch/onebyone/psbs/burst{BURST}")),
        mean_of(&format!("batch/grouped/psbs/burst{BURST}")),
    ) {
        derived.push(("batch_event_speedup".to_string(), one / grp));
    }
    if let Some(soa) = mean_of("soa/event/psbs/n10k") {
        derived.push(("soa_event_ns".to_string(), soa));
    }
    for (k, v) in &derived {
        println!("derived {k} = {v:.3}");
    }

    let path = bench::out_path("BENCH_sched.json");
    bench::write_json(&path, "sched", &b.samples, &derived).expect("write BENCH_sched.json");
    println!("wrote {path}");
}
