//! PJRT artifact execution cost: per-batch latency and per-job
//! throughput of the compiled workload and analytics graphs.  These are
//! the L2/L1 hot paths; EXPERIMENTS.md §Perf tracks them before/after
//! kernel changes.  Skipped (with a notice) when artifacts are absent.

use psbs::metrics;
use psbs::runtime::Runtime;
use psbs::util::bench::{self, Bench};
use psbs::util::rng::Rng;

fn main() {
    let Some(rt) = Runtime::try_default() else {
        eprintln!("artifacts/ not found — run `make artifacts`; runtime bench skipped");
        return;
    };
    let b = &mut Bench::new();
    let batch = rt.manifest.batch;
    println!("# AOT batch = {batch}");

    // Workload graph: uniforms -> Weibull samples + error multipliers.
    let mut rng = Rng::new(1);
    let u1: Vec<f32> = (0..batch).map(|_| rng.u01() as f32).collect();
    let u2: Vec<f32> = (0..batch).map(|_| rng.u01() as f32).collect();
    let u3: Vec<f32> = (0..batch).map(|_| rng.u01() as f32).collect();
    let params = [0.25f32, 1.0 / 24.0, 0.5, 0.0];
    {
        let rt = &rt;
        let (u1, u2, u3) = (u1.clone(), u2.clone(), u3.clone());
        b.bench_items("runtime/workload_batch", Some(batch as u64), move || {
            let out = rt.gen_batch(&u1, &u2, &u3, &params).unwrap();
            std::hint::black_box(out.0.len());
        });
    }

    // Analytics graph over one batch.
    let sizes: Vec<f64> = (0..batch).map(|i| 0.01 + (i % 97) as f64 * 0.1).collect();
    let sojourns: Vec<f64> = sizes.iter().map(|s| s * 3.0).collect();
    let idx: Vec<i32> = (0..batch).map(|i| (i % rt.manifest.num_bins) as i32).collect();
    let thr = metrics::log_thresholds(rt.manifest.num_thresholds, 3.0);
    {
        let rt = &rt;
        let (sizes, sojourns, idx, thr) =
            (sizes.clone(), sojourns.clone(), idx.clone(), thr.clone());
        b.bench_items("runtime/analytics_batch", Some(batch as u64), move || {
            let out = rt.analyze(&sizes, &sojourns, &idx, &thr).unwrap();
            std::hint::black_box(out.count);
        });
    }

    // Pure-rust fallback for the same aggregation, for the L2-vs-L3
    // comparison recorded in EXPERIMENTS.md §Perf.
    {
        let jobs: Vec<psbs::sim::Job> = sizes
            .iter()
            .enumerate()
            .map(|(i, &s)| psbs::sim::Job::exact(i as u32, 0.0, s))
            .collect();
        let slow: Vec<f64> = sojourns.iter().zip(&sizes).map(|(so, si)| so / si).collect();
        let thr = thr.clone();
        b.bench_items("runtime/rust_fallback_equiv", Some(batch as u64), move || {
            let c = metrics::conditional_slowdown(&jobs, &slow, metrics::COND_BINS);
            let e = metrics::slowdown_ecdf(&slow, &thr);
            std::hint::black_box((c.len(), e.len()));
        });
    }

    // End-to-end generation throughput (chunked, includes uniform
    // generation on the rust side).
    {
        let rt = &rt;
        let n = batch * 2;
        b.bench_items("runtime/gen_weibull_lognormal_2batches", Some(n as u64), move || {
            let mut rng = Rng::new(9);
            let out = rt.gen_weibull_lognormal(&mut rng, n, 0.25, 1.0 / 24.0, 0.5).unwrap();
            std::hint::black_box(out.0.len());
        });
    }

    let path = bench::out_path("BENCH_runtime.json");
    bench::write_json(&path, "runtime", &b.samples, &[]).expect("write BENCH_runtime.json");
    println!("wrote {path}");
}
