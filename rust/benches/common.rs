//! Helpers shared by the scheduler benches (pulled in via `#[path]` —
//! this file is not a bench target itself).  Keeping the standing-
//! population methodology in one place guarantees the per-event
//! numbers in BENCH_sched.json and the population curves in
//! BENCH_psbs_ops.json stay comparable.

use psbs::sched;
use psbs::sim::{Job, JobStore, Scheduler};

/// Build a scheduler preloaded with `n` long pending jobs (dense ids
/// 0..n-1), plus the [`JobStore`] holding their rows.  Probe
/// iterations reuse row `n` via [`JobStore::upsert`], so the store
/// stays at n + 1 rows no matter how long a bench runs.
pub fn preload(policy: &str, n: usize) -> (Box<dyn Scheduler>, JobStore) {
    let mut s = sched::by_name(policy).unwrap();
    let mut store = JobStore::new();
    for i in 0..n as u32 {
        let size = 1e6 + i as f64; // long: nothing completes during the bench
        store.deliver(s.as_mut(), i as f64 * 1e-6, &Job::exact(i, i as f64 * 1e-6, size));
    }
    (s, store)
}

/// Upsert the probe row and deliver it — one arrival event.
pub fn probe(s: &mut dyn Scheduler, store: &mut JobStore, now: f64, job: &Job) {
    store.upsert(job);
    s.on_arrival(now, job.id, store);
}

/// Tiny probe-job size: completes (really and virtually) within one
/// bench step, returning the population to exactly `n`.
pub const TINY: f64 = 1e-10;
