//! Helpers shared by the scheduler benches (pulled in via `#[path]` —
//! this file is not a bench target itself).  Keeping the standing-
//! population methodology in one place guarantees the per-event
//! numbers in BENCH_sched.json and the population curves in
//! BENCH_psbs_ops.json stay comparable.

use psbs::sched;
use psbs::sim::{Job, Scheduler};

/// Build a scheduler preloaded with `n` long pending jobs.
pub fn preload(policy: &str, n: usize) -> Box<dyn Scheduler> {
    let mut s = sched::by_name(policy).unwrap();
    for i in 1..=n as u32 {
        let size = 1e6 + i as f64; // long: nothing completes during the bench
        s.on_arrival(i as f64 * 1e-6, &Job::exact(i, i as f64 * 1e-6, size));
    }
    s
}

/// Tiny probe-job size: completes (really and virtually) within one
/// bench step, returning the population to exactly `n`.
pub const TINY: f64 = 1e-10;
