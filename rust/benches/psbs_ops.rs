//! The §5.2.2 complexity claim: per-event cost of the O(log n)
//! virtual-lag PSBS vs the classic O(n) FSP as the number of
//! concurrent jobs grows.  The paper's point — "our implementation of
//! PSBS is also the first O(log n) implementation of FSP" — shows as
//! a flat-ish PSBS line vs a linearly growing fsp-naive line.
//!
//! Methodology: each iteration submits one *tiny* job and advances the
//! scheduler just far enough to complete it, i.e. one full
//! arrival+completion event pair against a standing population of `n`
//! long jobs.  The tiny job completes in both the real and the virtual
//! system within the step, so the population returns to exactly `n`
//! after every iteration — no drift, no zombies.  fsp-naive pays its
//! O(n) virtual-remaining update inside `advance`; PSBS pays two heap
//! operations.

use psbs::sched::late_set::{LateMode, LateSet};
use psbs::sched::MinHeap;
use psbs::sim::{Job, Scheduler};
use psbs::util::bench::{self, Bench};

#[path = "common.rs"]
mod common;
use common::{preload, probe, TINY};

/// Standing late-set member size: nothing completes during a bench.
const LATE_BIG: f64 = 1e9;
/// Probe remaining work for the `complete` path: above EPS (a member
/// must be admitted pending) but tiny next to the standing population.
const LATE_PROBE: f64 = 1e-6;

/// A late set preloaded with `n` members: weights vary (Dps), and LAS
/// members spread over 64 attained levels (the realistic shape — many
/// members, few levels).
fn preload_late(mode: LateMode, n: usize) -> LateSet {
    let mut s = LateSet::new(mode);
    for i in 0..n as u32 {
        let attained = (i % 64) as f64 * 10.0;
        let w = 1.0 + (i % 7) as f64 * 0.5;
        s.insert(i, w, LATE_BIG, LATE_BIG + attained);
    }
    s
}

fn main() {
    let mut b = Bench::new();

    // Seq-index backing trade-off (ROADMAP open item): the PSBS `O`
    // heap pays index maintenance on every sift swap of the
    // arrival/virtual-completion path (`heap/push_pop/` ~ the `event/`
    // cost) to make cancellation O(log n) (`heap/cancel/`).  Three
    // backings: `plain` (no index — O(n)-scan cancel), `map` (HashMap),
    // `dense` (Vec keyed by seq — what the PSBS `O` heap now uses; job
    // ids are dense).  `derived` summarizes dense-vs-map at n=100k.
    for &n in &[1_000usize, 100_000] {
        for mode in ["plain", "map", "dense"] {
            let build = |mode: &str| -> MinHeap<u64> {
                match mode {
                    "plain" => MinHeap::new(),
                    "map" => MinHeap::with_index(),
                    _ => MinHeap::with_dense_index(),
                }
            };
            // Standing population of n; each iteration pushes one entry
            // below the minimum and pops it — two sifts over the full
            // depth, index maintenance included (the event-path shape).
            {
                let mut h = build(mode);
                for i in 0..n as u64 {
                    h.push(1.0 + i as f64, i, i);
                }
                let mut seq = n as u64;
                b.bench(&format!("heap/push_pop/{mode}/n{n}"), move || {
                    seq += 1;
                    h.push(0.0, seq, seq);
                    std::hint::black_box(h.pop());
                });
            }
            // Cancellation path: push a random-depth entry, remove it
            // by seq (plain scans; indexed modes jump to the slot).
            {
                let mut h = build(mode);
                for i in 0..n as u64 {
                    h.push(1.0 + i as f64, i, i);
                }
                let mut seq = n as u64;
                b.bench(&format!("heap/cancel/{mode}/n{n}"), move || {
                    seq += 1;
                    h.push(0.5 + (seq % 997) as f64, seq, seq);
                    std::hint::black_box(h.remove_by_seq(seq));
                });
            }
        }
    }

    // Late-set engine costs (the §5.2.2 shared late-set subsystem):
    // insert / complete / cancel / scan against a standing population
    // of n late members in each sharing mode.  `scan` is the per-event
    // read the flat paths paid O(|L|) for (rates, LAS front group and
    // regroup boundary) — now O(1); the membership ops are O(log |L|).
    // `derived` summarizes the n = 1k -> 100k scaling (flat ratios =
    // the claim holds; a linear engine would scale ~100x).
    let late_modes = [
        (LateMode::Serial, "serial"),
        (LateMode::Ps, "ps"),
        (LateMode::Las, "las"),
        (LateMode::Dps, "dps"),
    ];
    for &n in &[1_000usize, 100_000] {
        for (mode, mname) in late_modes {
            // Admission + kill of a fresh member (population constant).
            {
                let mut s = preload_late(mode, n);
                let mut id = n as u32;
                b.bench(&format!("late_set/insert/{mname}/n{n}"), move || {
                    id += 1;
                    s.insert(id, 1.25, LATE_BIG, LATE_BIG + 30.0);
                    std::hint::black_box(s.cancel(id));
                });
            }
            // Kill at varying depth (the remaining work staggers the
            // member through the engine's ordering structure).
            {
                let mut s = preload_late(mode, n);
                let mut id = n as u32;
                b.bench(&format!("late_set/cancel/{mname}/n{n}"), move || {
                    id += 1;
                    let rem = LATE_BIG * (0.25 + (id % 997) as f64 * 1e-3);
                    s.insert(id, 1.0, rem, LATE_BIG + rem);
                    std::hint::black_box(s.cancel(id));
                });
            }
            // A member completion against the standing population.
            {
                let mut s = preload_late(mode, n);
                let mut id = n as u32;
                let mut now = 0.0_f64;
                let mut done = Vec::with_capacity(4);
                b.bench(&format!("late_set/complete/{mname}/n{n}"), move || {
                    id += 1;
                    let share = s.exclusive_share();
                    done.clear();
                    if mode == LateMode::Serial {
                        // Serial serves the head: complete it, then
                        // restore the population with a fresh member.
                        now += LATE_BIG;
                        s.advance(LATE_BIG, share, now, &mut done);
                        s.insert(id, 1.0, LATE_BIG, LATE_BIG);
                    } else {
                        // Admit a probe that finishes within one step.
                        s.insert(id, 1.0, LATE_PROBE, LATE_PROBE);
                        let share = s.exclusive_share();
                        let dt = s.next_event_dt(share).unwrap();
                        now += dt;
                        s.advance(dt, share, now, &mut done);
                    }
                    debug_assert!(!done.is_empty());
                    std::hint::black_box(done.len());
                });
            }
            // The per-event read: next completion / regroup boundary.
            {
                let s = preload_late(mode, n);
                b.bench(&format!("late_set/scan/{mname}/n{n}"), move || {
                    let share = s.exclusive_share();
                    std::hint::black_box(s.next_event_dt(share));
                    std::hint::black_box(s.served());
                });
            }
        }
    }

    for &n in &[100usize, 1_000, 10_000, 100_000] {
        for policy in ["psbs", "fsp-naive"] {
            if policy == "fsp-naive" && n > 10_000 {
                continue; // O(n) per event: the 100k line takes minutes
            }
            let (mut s, mut store) = preload(policy, n);
            let pid = n as u32;
            let mut now = n as f64 * 1e-6;
            let mut done = Vec::with_capacity(1);
            // Step long enough that the tiny job also completes
            // *virtually* within it (virtual lag advances dt / w_v, so
            // clearing a TINY virtual size against n+1 unit weights
            // needs dt > TINY * (n+1)) — this is what returns the
            // population to exactly n each iteration.
            let dt = TINY * 4.0 * (n as f64 + 2.0);
            b.bench(&format!("event/{policy}/n{n}"), move || {
                probe(s.as_mut(), &mut store, now, &Job::exact(pid, now, TINY));
                std::hint::black_box(s.next_event(now));
                done.clear();
                s.advance(now, now + dt, &store, &mut done);
                debug_assert_eq!(done.len(), 1);
                now += dt;
                std::hint::black_box(done.len());
            });
        }
    }

    // Pure arrival cost (population grows during the measurement —
    // the amortized O(1)-heap-push framing of Algorithm 1; the store
    // grows with it, exactly as the engine's would).
    for &n in &[10_000usize, 100_000] {
        let (mut s, mut store) = preload("psbs", n);
        let mut now = n as f64 * 1e-6;
        b.bench(&format!("arrival_nocancel/psbs/n{n}"), move || {
            now += 1e-9;
            let id = store.push(&Job::exact(store.next_id(), now, 1e9));
            s.on_arrival(now, id, &store);
            std::hint::black_box(s.next_event(now));
        });
    }

    // Cancellation cost at depth: the O heap is indexed (seq -> slot),
    // so cancel is an O(1) lookup + O(log n) heap fix-up, no scan.
    // The cancelled job parks in E until its (tiny) virtual lag is
    // reached; the advance drains it so E stays empty.
    for &n in &[1_000usize, 100_000] {
        let (mut s, mut store) = preload("psbs", n);
        let pid = n as u32;
        let mut now = n as f64 * 1e-6;
        let mut done = Vec::new();
        let dt = TINY * 4.0 * (n as f64 + 2.0);
        b.bench(&format!("cancel/psbs/n{n}"), move || {
            let job = Job { id: pid, arrival: now, size: 1e9, est: TINY, weight: 1.0 };
            probe(s.as_mut(), &mut store, now, &job);
            assert!(s.cancel(now, pid), "cancel fresh job");
            done.clear();
            s.advance(now, now + dt, &store, &mut done);
            now += dt;
        });
    }

    // Estimate-refinement costs (the `on_estimate_update` path): the
    // native override vs the emulated trait default (cancel +
    // re-admit) on the srpte hybrid.  Two shapes: `srpte` re-keys a
    // standing waiter at varying heap depth — both paths pay the same
    // two O(log n) sifts there, the override's value is semantics
    // (attained-service reset, late-set boundary), not speed — and
    // `srpte_slot` refreshes the *serving* job to an estimate that
    // still beats every waiter, where the native fast path re-keys the
    // slot in place (zero heap traffic) while the default pays a
    // pop + push over the full waiting heap.  `derived` summarizes the
    // slot-path win at n = 100k (`est_update_native_speedup`,
    // informational in bench-compare, never gated).
    for &n in &[1_000usize, 100_000] {
        // Waiting-depth re-key through the native override.
        {
            let (mut s, mut store) = preload("srpte", n);
            let mut seq = 0u64;
            b.bench(&format!("est/update/native/srpte/n{n}"), move || {
                seq += 1;
                let id = (seq % n as u64) as u32;
                store.update_est(id, 1e6 * (0.5 + (seq % 997) as f64 * 1e-3));
                assert!(s.on_estimate_update(1.0, id, &store));
            });
        }
        // The same churn through the trait default's body.
        {
            let (mut s, mut store) = preload("srpte", n);
            let mut seq = 0u64;
            b.bench(&format!("est/update/readmit/srpte/n{n}"), move || {
                seq += 1;
                let id = (seq % n as u64) as u32;
                store.update_est(id, 1e6 * (0.5 + (seq % 997) as f64 * 1e-3));
                assert!(s.cancel(1.0, id));
                s.on_arrival(1.0, id, &store);
            });
        }
        // Serving-job refresh: the update keeps the job ahead of every
        // waiter (ests 500..1497 vs a standing 1e6+ population), so
        // the native path never touches the heap.
        for variant in ["native", "readmit"] {
            let (mut s, mut store) = preload("srpte", n);
            let pid = n as u32;
            store.deliver(
                s.as_mut(),
                1.0,
                &Job { id: pid, arrival: 1.0, size: 1e6, est: 1e3, weight: 1.0 },
            );
            let native = variant == "native";
            let mut seq = 0u64;
            b.bench(&format!("est/update/{variant}/srpte_slot/n{n}"), move || {
                seq += 1;
                store.update_est(pid, 500.0 + (seq % 997) as f64);
                if native {
                    assert!(s.on_estimate_update(1.0, pid, &store));
                } else {
                    assert!(s.cancel(1.0, pid));
                    s.on_arrival(1.0, pid, &store);
                }
            });
        }
    }

    // Derived trade-off summary (n = 100k): what the event path pays
    // for each index backing, and what cancellation gains from it.
    let mean_of = |name: &str| b.samples.iter().find(|s| s.name == name).map(|s| s.mean_ns);
    let mut derived: Vec<(String, f64)> = Vec::new();
    let pairs = [
        ("dense_vs_map_event", "heap/push_pop/map/n100000", "heap/push_pop/dense/n100000"),
        ("dense_vs_map_cancel", "heap/cancel/map/n100000", "heap/cancel/dense/n100000"),
        ("index_cost_event", "heap/push_pop/dense/n100000", "heap/push_pop/plain/n100000"),
        ("scan_vs_dense_cancel", "heap/cancel/plain/n100000", "heap/cancel/dense/n100000"),
        // Late-set population scaling, 1k -> 100k members: ~1 means the
        // O(log |L|) / O(1)-scan claim holds (a flat engine would pay
        // ~100x).  Informational in bench-compare, never gated.
        ("late_set_insert_scaling", "late_set/insert/dps/n100000", "late_set/insert/dps/n1000"),
        ("late_set_cancel_scaling", "late_set/cancel/dps/n100000", "late_set/cancel/dps/n1000"),
        (
            "late_set_complete_scaling",
            "late_set/complete/dps/n100000",
            "late_set/complete/dps/n1000",
        ),
        ("late_set_scan_scaling", "late_set/scan/las/n100000", "late_set/scan/las/n1000"),
        // What the serving-slot fast path of the native
        // `on_estimate_update` override saves over the cancel+readmit
        // default.  Informational in bench-compare, never gated.
        (
            "est_update_native_speedup",
            "est/update/readmit/srpte_slot/n100000",
            "est/update/native/srpte_slot/n100000",
        ),
    ];
    for (label, num, den) in pairs {
        if let (Some(a), Some(c)) = (mean_of(num), mean_of(den)) {
            derived.push((label.to_string(), a / c));
        }
    }
    for (k, v) in &derived {
        println!("derived {k} = {v:.2}x");
    }

    let path = bench::out_path("BENCH_psbs_ops.json");
    bench::write_json(&path, "psbs_ops", &b.samples, &derived).expect("write BENCH_psbs_ops.json");
    println!("wrote {path}");
}
