//! The §5.2.2 complexity claim: per-event cost of the O(log n)
//! virtual-lag PSBS vs the classic O(n) FSP as the number of
//! concurrent jobs grows.  The paper's point — "our implementation of
//! PSBS is also the first O(log n) implementation of FSP" — shows as
//! a flat-ish PSBS line vs a linearly growing fsp-naive line.
//!
//! Methodology: each iteration submits one *tiny* job and advances the
//! scheduler just far enough to complete it, i.e. one full
//! arrival+completion event pair against a standing population of `n`
//! long jobs.  The tiny job completes in both the real and the virtual
//! system within the step, so the population returns to exactly `n`
//! after every iteration — no drift, no zombies.  fsp-naive pays its
//! O(n) virtual-remaining update inside `advance`; PSBS pays two heap
//! operations.

use psbs::sim::{Job, Scheduler};
use psbs::util::bench::{self, Bench};

#[path = "common.rs"]
mod common;
use common::{preload, TINY};

fn main() {
    let mut b = Bench::new();

    for &n in &[100usize, 1_000, 10_000, 100_000] {
        for policy in ["psbs", "fsp-naive"] {
            if policy == "fsp-naive" && n > 10_000 {
                continue; // O(n) per event: the 100k line takes minutes
            }
            let mut s = preload(policy, n);
            let mut id = n as u32;
            let mut now = n as f64 * 1e-6;
            let mut done = Vec::with_capacity(1);
            // Step long enough that the tiny job also completes
            // *virtually* within it (virtual lag advances dt / w_v, so
            // clearing a TINY virtual size against n+1 unit weights
            // needs dt > TINY * (n+1)) — this is what returns the
            // population to exactly n each iteration.
            let dt = TINY * 4.0 * (n as f64 + 2.0);
            b.bench(&format!("event/{policy}/n{n}"), move || {
                id += 1;
                s.on_arrival(now, &Job::exact(id, now, TINY));
                std::hint::black_box(s.next_event(now));
                done.clear();
                s.advance(now, now + dt, &mut done);
                debug_assert_eq!(done.len(), 1);
                now += dt;
                std::hint::black_box(done.len());
            });
        }
    }

    // Pure arrival cost (population grows during the measurement —
    // the amortized O(1)-heap-push framing of Algorithm 1).
    for &n in &[10_000usize, 100_000] {
        let mut s = preload("psbs", n);
        let mut id = n as u32;
        let mut now = n as f64 * 1e-6;
        b.bench(&format!("arrival_nocancel/psbs/n{n}"), move || {
            now += 1e-9;
            id += 1;
            s.on_arrival(now, &Job::exact(id, now, 1e9));
            std::hint::black_box(s.next_event(now));
        });
    }

    // Cancellation cost at depth: the O heap is indexed (seq -> slot),
    // so cancel is an O(1) lookup + O(log n) heap fix-up, no scan.
    // The cancelled job parks in E until its (tiny) virtual lag is
    // reached; the advance drains it so E stays empty.
    for &n in &[1_000usize, 100_000] {
        let mut s = preload("psbs", n);
        let mut id = n as u32;
        let mut now = n as f64 * 1e-6;
        let mut done = Vec::new();
        let dt = TINY * 4.0 * (n as f64 + 2.0);
        b.bench(&format!("cancel/psbs/n{n}"), move || {
            id += 1;
            s.on_arrival(now, &Job { id, arrival: now, size: 1e9, est: TINY, weight: 1.0 });
            assert!(s.cancel(now, id), "cancel fresh job");
            done.clear();
            s.advance(now, now + dt, &mut done);
            now += dt;
        });
    }

    let path = bench::out_path("BENCH_psbs_ops.json");
    bench::write_json(&path, "psbs_ops", &b.samples, &[]).expect("write BENCH_psbs_ops.json");
    println!("wrote {path}");
}
