//! `psbs serve` round-trip tests — the PR 9 headline invariant: a live
//! session at `--speedup inf` is *bit-identical* to an offline replay
//! of the same rows (completion times, sojourns, and the final metrics
//! snapshot), across policies and ingress-queue capacities (so
//! backpressure provably never changes results, only timing).  Plus
//! the protocol edges: kill acks and distinct nacks, the `update`
//! verb (estimate refinement acks, reordering, and its three nacks),
//! the `stats` verb and cadence, malformed lines that do not kill the
//! session, `shutdown` aborts, and a paced (finite-speedup) smoke run.

use psbs::metrics::OnlineMetrics;
use psbs::sched;
use psbs::serve::{job_from_row, serve_session, ServeConfig, SessionSummary};
use psbs::sim::{self, Completion, CompletionSink, Job, SliceSource};
use psbs::workload::trace_file::parse;
use std::io::Cursor;

/// Offline baseline sink: dense completion times + the same
/// [`OnlineMetrics`] accumulation a served session performs.
struct Baseline {
    completion: Vec<f64>,
    metrics: OnlineMetrics,
}

impl CompletionSink for Baseline {
    fn on_arrival(&mut self, now: f64, job: &Job) {
        self.metrics.on_arrival(now, job);
    }
    fn on_completion(&mut self, time: f64, c: &Completion) {
        self.completion[c.id as usize] = c.time;
        self.metrics.on_completion(time, c);
    }
}

/// Deterministic protocol trace: all four columns, arrival ties every
/// third row (exercising burst coalescing), varied weights and
/// deliberately wrong estimates.
fn sample_csv() -> String {
    let mut text = String::from("arrival,size,weight,estimate\n");
    let mut t = 0.0f64;
    for i in 0..300u32 {
        if i % 3 != 0 {
            t += 0.37 + (i % 7) as f64 * 0.11;
        }
        let size = 1.0 + ((i as u64 * 7919) % 97) as f64;
        let w = 1 + i % 3;
        let est = size * (0.5 + (i % 11) as f64 * 0.1);
        text.push_str(&format!("{t},{size},{w},{est}\n"));
    }
    text
}

/// Run one in-process session over `Cursor`/`Vec<u8>` transports.
fn serve_lines(input: &str, cfg: &ServeConfig) -> (SessionSummary, Vec<String>) {
    let mut out: Vec<u8> = Vec::new();
    let summary = serve_session(Cursor::new(input.to_string()), &mut out, cfg).unwrap();
    let text = String::from_utf8(out).unwrap();
    (summary, text.lines().map(str::to_string).collect())
}

fn free_run(policy: &str) -> ServeConfig {
    ServeConfig { policy: policy.to_string(), speedup: f64::INFINITY, ..ServeConfig::default() }
}

/// `key=value` field of a protocol line, parsed as f64.
fn field(line: &str, key: &str) -> f64 {
    let pat = format!("{key}=");
    line.split_whitespace()
        .find_map(|w| w.strip_prefix(pat.as_str()))
        .unwrap_or_else(|| panic!("no `{pat}` in `{line}`"))
        .parse()
        .unwrap_or_else(|_| panic!("unparseable `{pat}` in `{line}`"))
}

/// The headline: serve the sample rows at `--speedup inf` and compare
/// every completion (bitwise) and the final stats line (byte for
/// byte) against the offline streaming replay of the same rows —
/// across policies, and across queue capacities down to 1, where the
/// reader parks on every single row.
#[test]
fn free_run_session_is_bit_identical_to_offline_replay() {
    let csv = sample_csv();
    let rows = parse(&csv).unwrap();
    let jobs: Vec<Job> =
        rows.iter().enumerate().map(|(i, r)| job_from_row(i as u32, r)).collect();
    let input = format!("{csv}drain\n");

    for policy in ["psbs", "srpte", "las", "fifo", "ps"] {
        let mut s = sched::by_name(policy).unwrap();
        let mut src = SliceSource::new(&jobs);
        let mut base =
            Baseline { completion: vec![f64::NAN; jobs.len()], metrics: OnlineMetrics::new() };
        sim::run_streaming(s.as_mut(), &mut src, &mut base);

        for queue in [1usize, 7, 1024] {
            let cfg = ServeConfig { queue, ..free_run(policy) };
            let (summary, lines) = serve_lines(&input, &cfg);
            assert_eq!(summary.delivered, jobs.len() as u64, "{policy} q={queue}");
            assert_eq!(summary.completed, jobs.len() as u64, "{policy} q={queue}");
            assert_eq!(summary.killed, 0);
            assert!(!summary.aborted);
            assert!(
                !lines.iter().any(|l| l.starts_with("err")),
                "{policy} q={queue}: unexpected err lines"
            );

            let done: Vec<&String> = lines.iter().filter(|l| l.starts_with("done ")).collect();
            assert_eq!(done.len(), jobs.len(), "{policy} q={queue}");
            for l in &done {
                let id = field(l, "id") as usize;
                let t = field(l, "t");
                assert_eq!(
                    t.to_bits(),
                    base.completion[id].to_bits(),
                    "{policy} q={queue}: job {id} completion drifted: {l}"
                );
                let sojourn = field(l, "sojourn");
                assert_eq!(
                    sojourn.to_bits(),
                    (base.completion[id] - jobs[id].arrival).to_bits(),
                    "{policy} q={queue}: job {id} sojourn drifted: {l}"
                );
            }

            // Final stats line == the offline accumulator's snapshot,
            // byte for byte (same completions folded in the same
            // order → bitwise-equal compensated sums).
            assert_eq!(
                lines[lines.len() - 2],
                format!("stats {}", base.metrics.snapshot()),
                "{policy} q={queue}"
            );
            assert_eq!(
                lines[lines.len() - 1],
                format!("bye delivered={n} completed={n} killed=0 aborted=false", n = jobs.len()),
                "{policy} q={queue}"
            );
        }
    }
}

/// Kill path, live: a pending job is cancelled and acked (`killed 1`),
/// an id never submitted is nacked distinctly, and the freed processor
/// serves the survivor to its exact completion.
#[test]
fn kill_acks_and_unknown_id_nacks() {
    let input = "0,100\n0,50\nkill 1\nkill 7\ndrain\n";
    let (summary, lines) = serve_lines(input, &free_run("psbs"));
    assert_eq!(
        lines,
        vec![
            "ok psbs serve policy=psbs speedup=inf queue=1024",
            "killed 1",
            "err kill 7: unknown id",
            "done id=0 t=100 sojourn=100 slowdown=1",
            "stats completed=1 active=0 mst=100 mean_slowdown=1",
            "bye delivered=2 completed=1 killed=1 aborted=false",
        ]
    );
    assert_eq!((summary.delivered, summary.completed, summary.killed), (2, 1, 1));
}

/// Killing a job that already completed nacks `not pending` — and the
/// protocol-order barrier means the kill is applied only after every
/// earlier row has been admitted.
#[test]
fn kill_after_completion_nacks_not_pending() {
    let input = "0,1\n10,1\nkill 0\ndrain\n";
    let (summary, lines) = serve_lines(input, &free_run("psbs"));
    assert_eq!(
        lines,
        vec![
            "ok psbs serve policy=psbs speedup=inf queue=1024",
            "done id=0 t=1 sojourn=1 slowdown=1",
            "err kill 0: not pending",
            "done id=1 t=11 sojourn=1 slowdown=1",
            "stats completed=2 active=0 mst=1 mean_slowdown=1",
            "bye delivered=2 completed=2 killed=0 aborted=false",
        ]
    );
    assert_eq!(summary.killed, 0);
}

/// The `update` verb, live: a revised estimate re-keys srpte's order
/// (the natively-overridden [`psbs::sim::Scheduler::on_estimate_update`]
/// path), acked with the stored value, and the reordered schedule
/// completes at exact times.  Both jobs arrive at t=0; the update is a
/// protocol-order barrier applied before any service, flipping job 1
/// (est 200 -> 1) ahead of job 0 (est 100).
#[test]
fn update_acks_and_reorders_srpte() {
    let input = "0,64,1,100\n0,8,1,200\nupdate 1 1\ndrain\n";
    let (summary, lines) = serve_lines(input, &free_run("srpte"));
    assert_eq!(
        lines,
        vec![
            "ok psbs serve policy=srpte speedup=inf queue=1024",
            "updated 1 est=1",
            "done id=1 t=8 sojourn=8 slowdown=1",
            "done id=0 t=72 sojourn=72 slowdown=1.125",
            "stats completed=2 active=0 mst=40 mean_slowdown=1.0625",
            "bye delivered=2 completed=2 killed=0 aborted=false",
        ]
    );
    assert_eq!((summary.delivered, summary.completed, summary.killed), (2, 2, 0));
}

/// The update nacks, live and in protocol order: an id never submitted
/// nacks `unknown id`; a completed job nacks `not pending` (the
/// barrier applies only after the preceding row was admitted, well
/// past job 0's completion).
#[test]
fn update_unknown_and_completed_nacks() {
    let input = "0,1\nupdate 7 2\n10,4\nupdate 0 5\ndrain\n";
    let (summary, lines) = serve_lines(input, &free_run("psbs"));
    assert_eq!(
        lines,
        vec![
            "ok psbs serve policy=psbs speedup=inf queue=1024",
            "err update 7: unknown id",
            "done id=0 t=1 sojourn=1 slowdown=1",
            "err update 0: not pending",
            "done id=1 t=14 sojourn=4 slowdown=1",
            "stats completed=2 active=0 mst=2.5 mean_slowdown=1",
            "bye delivered=2 completed=2 killed=0 aborted=false",
        ]
    );
    assert_eq!(summary.killed, 0);
}

/// The third nack: a nonpreemptive discipline's serving job rides the
/// trait-default cancel + re-admit path, whose cancel refusal surfaces
/// as the "unsupported" nack — the job still runs to completion.
#[test]
fn update_of_a_started_nonpreemptive_job_nacks_unsupported() {
    let input = "0,8\nupdate 0 2\ndrain\n";
    let (summary, lines) = serve_lines(input, &free_run("spt"));
    assert_eq!(
        lines,
        vec![
            "ok psbs serve policy=spt speedup=inf queue=1024",
            "err update 0: policy does not support estimate updates",
            "done id=0 t=8 sojourn=8 slowdown=1",
            "stats completed=1 active=0 mst=8 mean_slowdown=1",
            "bye delivered=1 completed=1 killed=0 aborted=false",
        ]
    );
    assert_eq!((summary.delivered, summary.completed), (1, 1));
}

/// The `stats` verb answers on demand (here: one job in flight,
/// nothing completed — NaN means, exactly as the snapshot renders
/// them), and `stats_every` adds a cadence line every N completions.
#[test]
fn stats_on_demand_and_on_cadence() {
    let input = "0,1\nstats\ndrain\n";
    let (_, lines) = serve_lines(input, &free_run("psbs"));
    assert_eq!(
        lines,
        vec![
            "ok psbs serve policy=psbs speedup=inf queue=1024",
            "stats completed=0 active=1 mst=NaN mean_slowdown=NaN",
            "done id=0 t=1 sojourn=1 slowdown=1",
            "stats completed=1 active=0 mst=1 mean_slowdown=1",
            "bye delivered=1 completed=1 killed=0 aborted=false",
        ]
    );

    let cfg = ServeConfig { stats_every: 2, ..free_run("fifo") };
    let input = "0,1\n2,1\n4,1\n6,1\ndrain\n";
    let (_, lines) = serve_lines(input, &cfg);
    let stats: Vec<&String> = lines.iter().filter(|l| l.starts_with("stats ")).collect();
    // Cadence lines after completions 2 and 4, plus the final one.
    assert_eq!(stats.len(), 3, "{lines:?}");
    assert_eq!(stats[0], "stats completed=2 active=0 mst=1 mean_slowdown=1");
    assert_eq!(stats[1], "stats completed=4 active=0 mst=1 mean_slowdown=1");
    assert_eq!(stats[2], stats[1]);
}

/// A malformed row is answered with an `err line N: ...` and the
/// session keeps going — later rows still run.
#[test]
fn malformed_rows_do_not_kill_the_session() {
    let input = "0,1\nbogus,row\n2,1\ndrain\n";
    let (summary, lines) = serve_lines(input, &free_run("fifo"));
    assert_eq!(summary.delivered, 2);
    assert_eq!(summary.completed, 2);
    let errs: Vec<&String> = lines.iter().filter(|l| l.starts_with("err ")).collect();
    assert_eq!(errs.len(), 1);
    assert_eq!(errs[0], "err line 2: malformed row: `bogus` is not a number (column `arrival`)");
    assert_eq!(lines.iter().filter(|l| l.starts_with("done ")).count(), 2);
}

/// `shutdown` ends the session immediately: admitted work is
/// abandoned, and the summary says so.
#[test]
fn shutdown_aborts_in_flight_work() {
    let input = "0,1000\nshutdown\n";
    let (summary, lines) = serve_lines(input, &free_run("psbs"));
    assert!(summary.aborted);
    assert_eq!((summary.delivered, summary.completed), (1, 0));
    assert_eq!(lines.last().unwrap(), "bye delivered=1 completed=0 killed=0 aborted=true");
    assert_eq!(lines[lines.len() - 2], "stats completed=0 active=1 mst=NaN mean_slowdown=NaN");
}

/// Finite-speedup smoke: the paced clock (timed condvar waits, lazy
/// wall origin) drives the same session to the same completions —
/// 20 simulated seconds compressed to ~20 µs of wall pacing.
#[test]
fn paced_session_completes_everything() {
    let mut input = String::from("arrival,size\n");
    for i in 0..20 {
        input.push_str(&format!("{i},0.5\n"));
    }
    input.push_str("drain\n");
    let cfg = ServeConfig { speedup: 1.0e6, ..free_run("fifo") };
    let (summary, lines) = serve_lines(&input, &cfg);
    assert_eq!((summary.delivered, summary.completed), (20, 20));
    assert_eq!(lines.iter().filter(|l| l.starts_with("done ")).count(), 20);
    assert!(!lines.iter().any(|l| l.starts_with("err")));
}
