//! Store/batch refactor differential pin: the engine's batched
//! [`JobStore`] event loop against a per-job-delivery reference driver
//! that replicates the pre-batching loop verbatim (one `on_arrival`
//! per job, no `on_arrival_batch` coalescing, no prefix retirement).
//! Together with the kept pre-refactor oracles in
//! `rust/tests/late_set_equiv.rs`, this pins the whole refactor:
//! completions bitwise identical, internal event counters equal, and
//! `active()` drains to 0 — across the full policy zoo, under random
//! same-instant arrival bursts, cancel churn, and fault churn.

use psbs::coordinator::{FaultConfig, FaultSpec, RetryPolicy};
use psbs::scenario::PolicySpec;
use psbs::sched;
use psbs::sim::{self, Job, JobStore, Scheduler};
use psbs::util::rng::Rng;
use psbs::workload::dists::{Dist, LogNormal, Weibull};

/// Random workload with deliberate same-instant bursts (~1/3 of
/// arrivals share the previous job's timestamp exactly), so the
/// engine's one-batch-per-instant coalescing really fires.
fn random_jobs(rng: &mut Rng, n: u32, sigma: f64) -> Vec<Job> {
    let w = Weibull::unit_mean(0.5 + rng.u01());
    let err = LogNormal::error_model(sigma);
    let mut t = 0.0;
    (0..n)
        .map(|i| {
            if rng.below(3) > 0 {
                t += rng.u01();
            }
            let s = w.sample(rng).max(1e-6);
            Job {
                id: i,
                arrival: t,
                size: s,
                est: (s * err.sample(rng)).max(1e-9),
                weight: 1.0 / (1.0 + rng.below(3) as f64),
            }
        })
        .collect()
}

/// The pre-batching event loop, replicated exactly: completions before
/// arrivals at ties (`e <= a`), `t.max(now)` clamp, one internal-event
/// count per non-arrival step — but every job delivered through a
/// separate `on_arrival` call and the store never retired.  Tolerates
/// lost jobs (fault drain): ends when both event streams dry up.
fn run_per_job(s: &mut dyn Scheduler, jobs: &[Job]) -> (Vec<f64>, u64) {
    let mut store = JobStore::new();
    let mut completion = vec![f64::NAN; jobs.len()];
    let mut done = Vec::new();
    let mut now = 0.0_f64;
    let mut events = 0u64;
    let mut next = 0usize;
    let mut completed = 0usize;
    loop {
        let next_arrival = jobs.get(next).map(|j| j.arrival);
        let next_internal = s.next_event(now);
        let (t, is_arrival) = match (next_arrival, next_internal) {
            (None, None) => break,
            (Some(a), None) => (a, true),
            (None, Some(e)) => (e, false),
            (Some(a), Some(e)) => {
                if e <= a {
                    (e, false)
                } else {
                    (a, true)
                }
            }
        };
        let t = t.max(now);
        done.clear();
        s.advance(now, t, &store, &mut done);
        for c in &done {
            completed += 1;
            completion[c.id as usize] = c.time;
        }
        now = t;
        if is_arrival {
            while next < jobs.len() && jobs[next].arrival <= now {
                let id = store.push(&jobs[next]);
                s.on_arrival(now, id, &store);
                next += 1;
            }
        } else {
            events += 1;
        }
        if completed == jobs.len() && next == jobs.len() {
            break;
        }
    }
    (completion, events)
}

fn assert_bitwise(name: &str, reference: &[f64], engine: &[f64]) {
    for (i, (x, y)) in reference.iter().zip(engine).enumerate() {
        let same = (x.is_nan() && y.is_nan()) || x.to_bits() == y.to_bits();
        assert!(same, "{name}: job {i} diverged: per-job {x} vs batched {y}");
    }
}

/// Fault-free churn: batched `sim::run` vs the per-job reference for
/// every discipline in the zoo.
#[test]
fn batched_engine_matches_per_job_reference_all_policies() {
    let mut rng = Rng::new(0x50A);
    for trial in 0..6u64 {
        let jobs = random_jobs(&mut rng, 120, 1.0 + (trial % 3) as f64 * 0.5);
        for policy in sched::ALL_POLICIES {
            let mut a = sched::by_name(policy).unwrap();
            let (want, ref_events) = run_per_job(a.as_mut(), &jobs);
            assert_eq!(a.active(), 0, "{policy} trial {trial}: per-job path leaked jobs");

            let mut b = sched::by_name(policy).unwrap();
            let r = sim::run(b.as_mut(), &jobs);
            assert_eq!(b.active(), 0, "{policy} trial {trial}: batched path leaked jobs");
            assert_eq!(r.events, ref_events, "{policy} trial {trial}: event counters");
            assert_bitwise(&format!("{policy} trial {trial}"), &want, &r.completion);
        }
    }
}

/// Drive a scheduler through arrivals plus a kill schedule, delivering
/// arrivals either per job or as one same-instant batch (the engine
/// shape).  Kills land after state is advanced, before same-instant
/// arrivals — the leader-loop order both real call sites use.
fn drive_kills(
    s: &mut dyn Scheduler,
    jobs: &[Job],
    kills: &[(f64, u32)],
    batched: bool,
) -> (Vec<f64>, Vec<bool>) {
    let mut store = JobStore::new();
    let mut completion = vec![f64::NAN; jobs.len()];
    let mut killed = vec![false; jobs.len()];
    let mut done = Vec::new();
    let mut now = 0.0_f64;
    let mut next = 0usize;
    let mut next_kill = 0usize;
    loop {
        let mut t = f64::INFINITY;
        for cand in [
            jobs.get(next).map(|j| j.arrival),
            s.next_event(now),
            kills.get(next_kill).map(|&(k, _)| k),
        ]
        .into_iter()
        .flatten()
        {
            t = t.min(cand);
        }
        if !t.is_finite() {
            break;
        }
        let t = t.max(now);
        done.clear();
        s.advance(now, t, &store, &mut done);
        for c in &done {
            completion[c.id as usize] = c.time;
        }
        now = t;
        while next_kill < kills.len() && kills[next_kill].0 <= now {
            let victim = kills[next_kill].1;
            if s.cancel(now, victim) {
                killed[victim as usize] = true;
            }
            next_kill += 1;
        }
        let first = store.next_id();
        while next < jobs.len() && jobs[next].arrival <= now {
            let id = store.push(&jobs[next]);
            if !batched {
                s.on_arrival(now, id, &store);
            }
            next += 1;
        }
        if batched && first < store.next_id() {
            s.on_arrival_batch(now, first..store.next_id(), &store);
        }
        if next == jobs.len() && next_kill == kills.len() && s.next_event(now).is_none() {
            break;
        }
    }
    assert_eq!(s.active(), 0, "active() must drain to 0");
    (completion, killed)
}

/// Cancel churn: same random kill schedule through both delivery
/// shapes, all policies — identical survivors, identical kill sets.
#[test]
fn batched_delivery_matches_per_job_under_cancel_churn() {
    let mut rng = Rng::new(0xC4A1);
    for trial in 0..5u64 {
        let jobs = random_jobs(&mut rng, 90, 1.3);
        let span = jobs.last().unwrap().arrival + 4.0;
        let mut kills: Vec<(f64, u32)> = (0..10)
            .map(|_| (rng.u01() * span, rng.below(jobs.len() as u64) as u32))
            .collect();
        kills.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        for policy in sched::ALL_POLICIES {
            let mut a = sched::by_name(policy).unwrap();
            let (want, killed_a) = drive_kills(a.as_mut(), &jobs, &kills, false);
            let mut b = sched::by_name(policy).unwrap();
            let (got, killed_b) = drive_kills(b.as_mut(), &jobs, &kills, true);
            assert_eq!(killed_a, killed_b, "{policy} trial {trial}: kill sets differ");
            assert_bitwise(&format!("{policy} trial {trial} (kills)"), &want, &got);
        }
    }
}

/// Fault churn: drain-mode engine vs the per-job reference with
/// crash/recover/retry schedules live, for every policy (wrapped in
/// the standard faulty cluster build).  Lost jobs keep NaN on both
/// sides; event counters include every crash/recovery/retry event.
#[test]
fn faulty_drain_matches_per_job_reference_all_policies() {
    let cfg = FaultConfig {
        spec: FaultSpec { mtbf: 8.0, mttr: 1.0, slowdown: 0.5 },
        retry: RetryPolicy { max_attempts: 2, backoff: 0.25 },
        seed: 11,
    };
    let mut rng = Rng::new(0xFA07);
    for trial in 0..3u64 {
        let jobs = random_jobs(&mut rng, 70, 1.2);
        for policy in sched::ALL_POLICIES {
            let spec = PolicySpec::from(*policy);
            let mut a = spec.build_faulty(5 + trial, &cfg);
            let (want, ref_events) = run_per_job(a.as_mut(), &jobs);
            assert_eq!(a.active(), 0, "{policy} trial {trial}: per-job path leaked jobs");

            let mut b = spec.build_faulty(5 + trial, &cfg);
            let r = sim::run_to_drain(b.as_mut(), &jobs);
            assert_eq!(b.active(), 0, "{policy} trial {trial}: batched path leaked jobs");
            assert_eq!(r.events, ref_events, "{policy} trial {trial}: event counters");
            assert_bitwise(&format!("{policy} trial {trial} (faulty)"), &want, &r.completion);
        }
    }
}
