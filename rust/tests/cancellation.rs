//! Cancellation (kill) bookkeeping — the §5.2.2 "additional
//! bookkeeping ... to handle jobs that complete even when they are not
//! scheduled (e.g. ... after being killed)".

use psbs::coordinator::{Service, ServiceConfig};
use psbs::sched;
use psbs::sim::{self, Job, Scheduler};
use psbs::util::check::{property, Config};
use psbs::util::rng::Rng;
use psbs::workload::dists::{Dist, LogNormal, Weibull};
use std::time::Duration;

fn random_jobs(rng: &mut Rng, size: usize, sigma: f64) -> Vec<Job> {
    let n = 4 + size * 2;
    let w = Weibull::unit_mean(0.4 + rng.u01());
    let err = LogNormal::error_model(sigma);
    let mut t = 0.0;
    (0..n as u32)
        .map(|i| {
            t += rng.u01();
            let s = w.sample(rng).max(1e-6);
            Job {
                id: i,
                arrival: t,
                size: s,
                est: (s * err.sample(rng)).max(1e-9),
                weight: 1.0 / (1.0 + rng.below(3) as f64),
            }
        })
        .collect()
}

/// Drive a scheduler manually, cancelling one job mid-flight, and
/// check every *other* job still completes (and none completes twice).
fn run_with_cancel(policy: &str, jobs: &[Job], victim: u32, cancel_at: f64) -> Vec<f64> {
    let mut s = sched::by_name(policy).unwrap();
    let mut completion = vec![f64::NAN; jobs.len()];
    let mut done = Vec::new();
    let mut now = 0.0;
    let mut next = 0usize;
    let mut cancelled = false;
    let mut killed = false; // cancel actually removed the victim
    loop {
        let next_arrival = jobs.get(next).map(|j| j.arrival);
        let next_internal = s.next_event(now);
        let cancel_t = if cancelled { None } else { Some(cancel_at) };
        // Earliest of the three event sources.
        let mut t = f64::INFINITY;
        for cand in [next_arrival, next_internal, cancel_t].into_iter().flatten() {
            t = t.min(cand);
        }
        if !t.is_finite() {
            break;
        }
        let t = t.max(now);
        done.clear();
        s.advance(now, t, &mut done);
        for c in &done {
            assert!(completion[c.id as usize].is_nan(), "job {} completed twice", c.id);
            assert!(!(killed && c.id == victim), "killed job must not complete");
            completion[c.id as usize] = c.time;
        }
        now = t;
        if Some(t) == cancel_t {
            // Cancel succeeds iff the victim has arrived and neither
            // completed nor been cancelled yet.
            let did = s.cancel(now, victim);
            let arrived = (victim as usize) < next;
            let already_done = !completion[victim as usize].is_nan();
            assert_eq!(
                did,
                arrived && !already_done,
                "cancel={did} arrived={arrived} done={already_done}"
            );
            cancelled = true;
            killed = did;
        }
        while next < jobs.len() && jobs[next].arrival <= now {
            s.on_arrival(now, &jobs[next]);
            next += 1;
        }
        if next == jobs.len() && s.next_event(now).is_none() {
            break;
        }
    }
    completion
}

#[test]
fn psbs_survives_cancellation() {
    property(
        "psbs cancel",
        Config { cases: 48, ..Default::default() },
        |rng, size| {
            let jobs = random_jobs(rng, size, 1.0);
            let victim = rng.below(jobs.len() as u64) as u32;
            let span = jobs.last().unwrap().arrival + 2.0;
            let cancel_at = rng.u01() * span;
            (jobs, victim, cancel_at)
        },
        |(jobs, victim, cancel_at)| {
            let completion = run_with_cancel("psbs", jobs, *victim, *cancel_at);
            // Every non-victim job completes; the victim completes only
            // if it beat the cancellation.
            for (i, c) in completion.iter().enumerate() {
                if i as u32 != *victim && c.is_nan() {
                    return Err(format!("job {i} never completed after cancelling {victim}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn srpte_survives_cancellation() {
    property(
        "srpte cancel",
        Config { cases: 48, seed: 3, ..Default::default() },
        |rng, size| {
            let jobs = random_jobs(rng, size, 1.0);
            let victim = rng.below(jobs.len() as u64) as u32;
            let span = jobs.last().unwrap().arrival + 2.0;
            (jobs, victim, rng.u01() * span)
        },
        |(jobs, victim, cancel_at)| {
            let completion = run_with_cancel("srpte", jobs, *victim, *cancel_at);
            for (i, c) in completion.iter().enumerate() {
                if i as u32 != *victim && c.is_nan() {
                    return Err(format!("job {i} never completed after cancelling {victim}"));
                }
            }
            Ok(())
        },
    );
}

/// Cancelling a job can only help the others (work disappears):
/// under PSBS no surviving job completes later than without the kill.
#[test]
fn cancellation_never_hurts_survivors_in_psbs() {
    property(
        "psbs cancel monotonicity",
        Config { cases: 48, seed: 7, ..Default::default() },
        |rng, size| {
            let jobs = random_jobs(rng, size, 0.7);
            let victim = rng.below(jobs.len() as u64) as u32;
            // Cancel at the victim's arrival instant + epsilon so it
            // definitely exists and has consumed negligible service.
            let cancel_at = jobs[victim as usize].arrival + 1e-9;
            (jobs, victim, cancel_at)
        },
        |(jobs, victim, cancel_at)| {
            let with_kill = run_with_cancel("psbs", jobs, *victim, *cancel_at);
            let mut s = sched::by_name("psbs").unwrap();
            let without = sim::run(s.as_mut(), jobs).completion;
            for i in 0..jobs.len() {
                if i as u32 == *victim {
                    continue;
                }
                if with_kill[i] > without[i] + 1e-6 {
                    return Err(format!(
                        "job {i} later with kill: {} vs {}",
                        with_kill[i], without[i]
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn cancel_of_unknown_id_is_noop() {
    let mut s = sched::by_name("psbs").unwrap();
    s.on_arrival(0.0, &Job::exact(0, 0.0, 1.0));
    assert!(!s.cancel(0.0, 99));
    assert!(s.cancel(0.0, 0));
    assert!(!s.cancel(0.0, 0), "double cancel must fail");
    assert_eq!(s.active(), 0);
}

#[test]
fn unsupporting_policies_report_false() {
    for policy in ["fifo", "ps", "las", "mlfq"] {
        let mut s = sched::by_name(policy).unwrap();
        s.on_arrival(0.0, &Job::exact(0, 0.0, 1.0));
        assert!(!s.cancel(0.0, 0), "{policy} should report no support");
    }
}

#[test]
fn service_kill_api() {
    let svc = Service::start(ServiceConfig { policy: "psbs".into(), speed: 1_000.0 });
    // A long job (id 0) and a quick one (id 1).
    let long_rx = svc.submit(10_000.0, 10_000.0, 1.0);
    let quick_rx = svc.submit(10.0, 10.0, 1.0);
    assert!(svc.kill(0), "long job should still be pending");
    let quick = quick_rx.recv_timeout(Duration::from_secs(10)).expect("quick job completes");
    assert_eq!(quick.job_id, 1);
    // The killed job's channel never fires.
    assert!(long_rx.recv_timeout(Duration::from_millis(50)).is_err());
    assert!(!svc.kill(0), "double kill reports false");
    assert!(!svc.kill(1), "completed job cannot be killed");
    let stats = svc.shutdown();
    assert_eq!(stats.completed, 1);
}
