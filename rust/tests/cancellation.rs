//! Cancellation (kill) bookkeeping — the §5.2.2 "additional
//! bookkeeping ... to handle jobs that complete even when they are not
//! scheduled (e.g. ... after being killed)".

use psbs::coordinator::{Service, ServiceConfig};
use psbs::sched;
use psbs::sim::{self, Job, JobStore, Scheduler};
use psbs::util::check::{property, Config};
use psbs::util::rng::Rng;
use psbs::workload::dists::{Dist, LogNormal, Weibull};
use std::time::Duration;

fn random_jobs(rng: &mut Rng, size: usize, sigma: f64) -> Vec<Job> {
    let n = 4 + size * 2;
    let w = Weibull::unit_mean(0.4 + rng.u01());
    let err = LogNormal::error_model(sigma);
    let mut t = 0.0;
    (0..n as u32)
        .map(|i| {
            t += rng.u01();
            let s = w.sample(rng).max(1e-6);
            Job {
                id: i,
                arrival: t,
                size: s,
                est: (s * err.sample(rng)).max(1e-9),
                weight: 1.0 / (1.0 + rng.below(3) as f64),
            }
        })
        .collect()
}

/// Drive a scheduler manually through a schedule of kill requests
/// (sorted by time), checking the §5.2.2 contract at every step:
/// cancel succeeds iff the victim has arrived and neither completed
/// nor been killed, killed jobs never complete, nothing completes
/// twice, and `active()` drains to 0.  Returns (completion, killed).
fn run_with_kills(policy: &str, jobs: &[Job], kills: &[(f64, u32)]) -> (Vec<f64>, Vec<bool>) {
    // Nonpreemptive disciplines additionally reject kills of the job
    // that has started service (documented in `sched::nonpreemptive`),
    // so for them `cancel` may refuse where a preemptive policy would
    // accept — but never the reverse.
    let nonpreemptive = matches!(policy, "spt" | "sjf");
    let mut s = sched::by_name(policy).unwrap();
    // The driver owns a store like the engine does; rows are kept (no
    // retirement) so assertions can index any id at any time.
    let mut store = JobStore::new();
    let mut completion = vec![f64::NAN; jobs.len()];
    let mut killed = vec![false; jobs.len()];
    let mut done = Vec::new();
    let mut now = 0.0;
    let mut next = 0usize;
    let mut next_kill = 0usize;
    loop {
        let next_arrival = jobs.get(next).map(|j| j.arrival);
        let next_internal = s.next_event(now);
        let kill_t = kills.get(next_kill).map(|&(t, _)| t);
        // Earliest of the three event sources.
        let mut t = f64::INFINITY;
        for cand in [next_arrival, next_internal, kill_t].into_iter().flatten() {
            t = t.min(cand);
        }
        if !t.is_finite() {
            break;
        }
        let t = t.max(now);
        done.clear();
        s.advance(now, t, &store, &mut done);
        for c in &done {
            assert!(
                completion[c.id as usize].is_nan(),
                "{policy}: job {} completed twice",
                c.id
            );
            assert!(!killed[c.id as usize], "{policy}: killed job {} completed", c.id);
            completion[c.id as usize] = c.time;
        }
        now = t;
        // Kills land before same-instant arrivals (as the leader loop
        // orders them: state advanced, then the request applies).
        while next_kill < kills.len() && kills[next_kill].0 <= now {
            let victim = kills[next_kill].1;
            let did = s.cancel(now, victim);
            let arrived = (victim as usize) < next;
            let expect =
                arrived && completion[victim as usize].is_nan() && !killed[victim as usize];
            if nonpreemptive {
                assert!(
                    expect || !did,
                    "{policy}: cancel({victim}) at {now} succeeded on a dead job"
                );
            } else {
                assert_eq!(
                    did, expect,
                    "{policy}: cancel({victim}) at {now}: got {did}, expected {expect}"
                );
            }
            if did {
                killed[victim as usize] = true;
            }
            next_kill += 1;
        }
        while next < jobs.len() && jobs[next].arrival <= now {
            let id = store.push(&jobs[next]);
            s.on_arrival(now, id, &store);
            next += 1;
        }
        if next == jobs.len() && next_kill == kills.len() && s.next_event(now).is_none() {
            break;
        }
    }
    assert_eq!(s.active(), 0, "{policy}: active() must drain to 0");
    (completion, killed)
}

/// Single-kill convenience wrapper (the original harness shape).
fn run_with_cancel(policy: &str, jobs: &[Job], victim: u32, cancel_at: f64) -> Vec<f64> {
    run_with_kills(policy, jobs, &[(cancel_at, victim)]).0
}

#[test]
fn psbs_survives_cancellation() {
    property(
        "psbs cancel",
        Config { cases: 48, ..Default::default() },
        |rng, size| {
            let jobs = random_jobs(rng, size, 1.0);
            let victim = rng.below(jobs.len() as u64) as u32;
            let span = jobs.last().unwrap().arrival + 2.0;
            let cancel_at = rng.u01() * span;
            (jobs, victim, cancel_at)
        },
        |(jobs, victim, cancel_at)| {
            let completion = run_with_cancel("psbs", jobs, *victim, *cancel_at);
            // Every non-victim job completes; the victim completes only
            // if it beat the cancellation.
            for (i, c) in completion.iter().enumerate() {
                if i as u32 != *victim && c.is_nan() {
                    return Err(format!("job {i} never completed after cancelling {victim}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn srpte_survives_cancellation() {
    property(
        "srpte cancel",
        Config { cases: 48, seed: 3, ..Default::default() },
        |rng, size| {
            let jobs = random_jobs(rng, size, 1.0);
            let victim = rng.below(jobs.len() as u64) as u32;
            let span = jobs.last().unwrap().arrival + 2.0;
            (jobs, victim, rng.u01() * span)
        },
        |(jobs, victim, cancel_at)| {
            let completion = run_with_cancel("srpte", jobs, *victim, *cancel_at);
            for (i, c) in completion.iter().enumerate() {
                if i as u32 != *victim && c.is_nan() {
                    return Err(format!("job {i} never completed after cancelling {victim}"));
                }
            }
            Ok(())
        },
    );
}

/// Cancelling a job can only help the others (work disappears):
/// under PSBS no surviving job completes later than without the kill.
#[test]
fn cancellation_never_hurts_survivors_in_psbs() {
    property(
        "psbs cancel monotonicity",
        Config { cases: 48, seed: 7, ..Default::default() },
        |rng, size| {
            let jobs = random_jobs(rng, size, 0.7);
            let victim = rng.below(jobs.len() as u64) as u32;
            // Cancel at the victim's arrival instant + epsilon so it
            // definitely exists and has consumed negligible service.
            let cancel_at = jobs[victim as usize].arrival + 1e-9;
            (jobs, victim, cancel_at)
        },
        |(jobs, victim, cancel_at)| {
            let with_kill = run_with_cancel("psbs", jobs, *victim, *cancel_at);
            let mut s = sched::by_name("psbs").unwrap();
            let without = sim::run(s.as_mut(), jobs).completion;
            for i in 0..jobs.len() {
                if i as u32 == *victim {
                    continue;
                }
                if with_kill[i] > without[i] + 1e-6 {
                    return Err(format!(
                        "job {i} later with kill: {} vs {}",
                        with_kill[i], without[i]
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn cancel_of_unknown_id_is_noop() {
    for policy in sched::ALL_POLICIES {
        let mut s = sched::by_name(policy).unwrap();
        let mut st = JobStore::new();
        st.deliver(s.as_mut(), 0.0, &Job::exact(0, 0.0, 1.0));
        assert!(!s.cancel(0.0, 99), "{policy}: unknown id");
        if matches!(*policy, "spt" | "sjf") {
            // Nonpreemptive: the just-delivered job is already serving
            // and rejects the kill; a waiting job cancels as usual.
            assert!(!s.cancel(0.0, 0), "{policy}: started job rejects the kill");
            st.deliver(s.as_mut(), 0.0, &Job::exact(1, 0.0, 1.0));
            assert!(s.cancel(0.0, 1), "{policy}: waiting job");
            assert!(!s.cancel(0.0, 1), "{policy}: double cancel must fail");
            assert_eq!(s.active(), 1, "{policy}: the serving job remains");
        } else {
            assert!(s.cancel(0.0, 0), "{policy}: pending job");
            assert!(!s.cancel(0.0, 0), "{policy}: double cancel must fail");
            assert_eq!(s.active(), 0, "{policy}");
        }
    }
}

/// The PR-5 gap pin: these disciplines used to inherit the
/// default-`false` `cancel` (so `Service::kill` silently failed for
/// half the zoo); every one of them must now really remove the job.
#[test]
fn formerly_unsupported_policies_now_cancel() {
    for policy in ["fifo", "ps", "dps", "las", "mlfq", "srpte+ps", "srpte+las"] {
        let mut s = sched::by_name(policy).unwrap();
        let mut st = JobStore::new();
        st.deliver(s.as_mut(), 0.0, &Job::exact(0, 0.0, 1.0));
        assert!(s.cancel(0.0, 0), "{policy} must support cancellation");
        assert_eq!(s.active(), 0, "{policy} must drop the killed job");
    }
}

/// Cancel-mid-churn over the WHOLE zoo: random kill schedules
/// interleaved with arrivals under heavy estimation error.  Killed
/// jobs never complete, everyone else does, `active()` drains to 0
/// (all asserted inside the harness for every step).
#[test]
fn cancel_mid_churn_property_all_policies() {
    property(
        "cancel mid-churn (all policies)",
        Config { cases: 20, max_size: 36, seed: 0xC4A11 },
        |rng, size| {
            let jobs = random_jobs(rng, size, 1.5);
            let span = jobs.last().unwrap().arrival + 4.0;
            let nkills = 1 + rng.below(1 + jobs.len() as u64 / 3) as usize;
            let mut kills: Vec<(f64, u32)> = (0..nkills)
                .map(|_| (rng.u01() * span, rng.below(jobs.len() as u64) as u32))
                .collect();
            kills.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            (jobs, kills)
        },
        |(jobs, kills)| {
            for policy in sched::ALL_POLICIES {
                let (completion, killed) = run_with_kills(policy, jobs, kills);
                for (i, c) in completion.iter().enumerate() {
                    if !killed[i] && c.is_nan() {
                        return Err(format!(
                            "{policy}: job {i} never completed (kills: {kills:?})"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn service_kill_api() {
    let svc = Service::start(ServiceConfig { policy: "psbs".into(), speed: 1_000.0 });
    // A long job (id 0) and a quick one (id 1).
    let long_rx = svc.submit(10_000.0, 10_000.0, 1.0);
    let quick_rx = svc.submit(10.0, 10.0, 1.0);
    assert!(svc.kill(0), "long job should still be pending");
    let quick = quick_rx.recv_timeout(Duration::from_secs(10)).expect("quick job completes");
    assert_eq!(quick.job_id, 1);
    // The killed job's channel never fires.
    assert!(long_rx.recv_timeout(Duration::from_millis(50)).is_err());
    assert!(!svc.kill(0), "double kill reports false");
    assert!(!svc.kill(1), "completed job cannot be killed");
    let stats = svc.shutdown();
    assert_eq!(stats.completed, 1);
    // Kill accounting: one real kill, two benign rejections, and no
    // silently-dropped (unsupported) kills anywhere in the zoo.
    assert_eq!(stats.killed, 1);
    assert_eq!(stats.kills_rejected, 2);
    assert_eq!(stats.kills_unsupported, 0);
}
