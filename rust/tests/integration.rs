//! Integration tests across runtime + metrics + workload: the compiled
//! AOT artifacts (PJRT) must agree with the pure-rust implementations
//! on identical inputs.  These tests require `artifacts/` (built by
//! `make artifacts`); they are skipped with a notice when absent so
//! `cargo test` works in a fresh checkout.

use psbs::metrics;
use psbs::runtime::Runtime;
use psbs::sim::Job;
use psbs::util::rng::Rng;
use psbs::workload::dists::{Dist, LogNormal, Weibull};

fn runtime() -> Option<Runtime> {
    // Tests run from the workspace root.
    let rt = Runtime::try_default();
    if rt.is_none() {
        // (note printed once per test binary run)
        eprintln!("NOTE: artifacts/ not found — integration tests skipped (run `make artifacts`)");
    }
    rt
}

/// The compiled Weibull inverse-CDF must match the rust `Dist::icdf`
/// on the same uniforms (f32 tolerance).
#[test]
fn workload_artifact_matches_rust_weibull() {
    let Some(rt) = runtime() else { return };
    let b = rt.manifest.batch;
    let mut rng = Rng::new(71);
    for shape in [0.25, 1.0, 2.0] {
        let w = Weibull::unit_mean(shape);
        let u: Vec<f32> = (0..b).map(|_| rng.u01() as f32).collect();
        let zeros = vec![0.5f32; b];
        let params = [shape as f32, w.scale as f32, 0.5, 0.0];
        let (samples, _) = rt.gen_batch(&u, &zeros, &zeros, &params).unwrap();
        for i in (0..b).step_by(97) {
            let expect = w.icdf(u[i] as f64);
            let got = samples[i] as f64;
            let tol = 1e-3 * expect.abs().max(1e-3);
            assert!(
                (got - expect).abs() < tol.max(expect * 5e-3),
                "shape {shape} i {i}: artifact {got} vs rust {expect}"
            );
        }
    }
}

/// The compiled log-normal error multiplier has median ~1 and the
/// right spread (it uses Box–Muller inside the kernel, so we check
/// moments, not pointwise values).
#[test]
fn workload_artifact_lognormal_moments() {
    let Some(rt) = runtime() else { return };
    let b = rt.manifest.batch;
    let mut rng = Rng::new(72);
    let sigma = 0.5;
    let u: Vec<f32> = (0..b).map(|_| rng.u01() as f32).collect();
    let ua: Vec<f32> = (0..b).map(|_| rng.u01() as f32).collect();
    let ub: Vec<f32> = (0..b).map(|_| rng.u01() as f32).collect();
    let params = [0.25, 1.0, sigma as f32, 0.0];
    let (_, mults) = rt.gen_batch(&u, &ua, &ub, &params).unwrap();
    let mut logs: Vec<f64> = mults.iter().map(|&m| (m as f64).ln()).collect();
    logs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = logs[logs.len() / 2];
    let sd = psbs::stats::stddev(&logs);
    assert!(median.abs() < 0.05, "log-median {median} should be ~0");
    assert!((sd - sigma).abs() < 0.05, "log-sd {sd} should be ~{sigma}");
}

/// End-to-end agreement: analytics artifact vs pure-rust metrics on a
/// simulated PSBS run.
#[test]
fn analytics_artifact_matches_rust_metrics() {
    let Some(rt) = runtime() else { return };
    let cfg = psbs::workload::SynthConfig::default().with_njobs(3_000);
    let jobs = psbs::workload::synthesize(&cfg, 5);
    let mut s = psbs::sched::by_name("psbs").unwrap();
    let res = psbs::sim::run(s.as_mut(), &jobs);

    let sizes: Vec<f64> = jobs.iter().map(|j| j.size).collect();
    let sojourns: Vec<f64> = res.sojourns(&jobs);
    let idx = metrics::bin_indices(&jobs, rt.manifest.num_bins);
    let thr = metrics::log_thresholds(rt.manifest.num_thresholds, 3.0);
    let out = rt.analyze(&sizes, &sojourns, &idx, &thr).unwrap();

    // MST (f32 accumulation tolerance).
    let rust_mst = res.mst(&jobs);
    assert!(
        (out.mst() - rust_mst).abs() / rust_mst < 1e-3,
        "artifact MST {} vs rust {rust_mst}",
        out.mst()
    );
    assert_eq!(out.count as usize, jobs.len());

    // Per-job slowdowns.
    let rust_slow = res.slowdowns(&jobs);
    for i in (0..jobs.len()).step_by(53) {
        let tol = 1e-3 * rust_slow[i].abs().max(1.0);
        assert!(
            (out.slowdowns[i] - rust_slow[i]).abs() < tol,
            "slowdown {i}: artifact {} vs rust {}",
            out.slowdowns[i],
            rust_slow[i]
        );
    }

    // Conditional slowdown per class.
    let rust_cond = metrics::conditional_slowdown(&jobs, &rust_slow, rt.manifest.num_bins);
    let art_cond = out.conditional_slowdown();
    assert_eq!(rust_cond.len(), art_cond.len());
    for (i, (&(_, r), &a)) in rust_cond.iter().zip(&art_cond).enumerate() {
        assert!(
            (r - a).abs() / r.abs().max(1.0) < 5e-3,
            "class {i}: artifact {a} vs rust {r}"
        );
    }

    // ECDF counts.  A large mass of jobs sits within floating-point
    // rounding of slowdown == 1.0 (jobs served without interference),
    // so an exact comparison at the threshold is ill-posed: bound the
    // artifact's (f32) count by the rust ECDF evaluated at thresholds
    // nudged a relative 1e-4 either way.
    let thr_lo: Vec<f64> = thr.iter().map(|t| t * (1.0 - 1e-4)).collect();
    let thr_hi: Vec<f64> = thr.iter().map(|t| t * (1.0 + 1e-4)).collect();
    let ecdf_lo = metrics::slowdown_ecdf(&rust_slow, &thr_lo);
    let ecdf_hi = metrics::slowdown_ecdf(&rust_slow, &thr_hi);
    for i in 0..thr.len() {
        let art_frac = out.ecdf_counts[i] / jobs.len() as f64;
        assert!(
            art_frac >= ecdf_lo[i] - 2e-3 && art_frac <= ecdf_hi[i] + 2e-3,
            "ecdf[{i}]: artifact {art_frac} outside rust bounds [{}, {}]",
            ecdf_lo[i],
            ecdf_hi[i]
        );
    }
}

/// Chunking over the fixed AOT batch must be linear: results over a
/// population larger than one batch equal the pure-rust aggregates.
#[test]
fn analytics_chunking_is_linear() {
    let Some(rt) = runtime() else { return };
    let n = rt.manifest.batch + 1234; // forces 2 chunks + padding
    let mut rng = Rng::new(99);
    let w = Weibull::unit_mean(0.5);
    let jobs: Vec<Job> = (0..n as u32)
        .map(|i| {
            let s = w.sample(&mut rng).max(1e-6);
            Job::exact(i, 0.0, s)
        })
        .collect();
    let sojourns: Vec<f64> = jobs.iter().map(|j| j.size * (1.0 + rng.u01())).collect();
    let slow: Vec<f64> = jobs.iter().zip(&sojourns).map(|(j, s)| s / j.size).collect();
    let sizes: Vec<f64> = jobs.iter().map(|j| j.size).collect();
    let idx = metrics::bin_indices(&jobs, rt.manifest.num_bins);
    let thr = metrics::log_thresholds(rt.manifest.num_thresholds, 3.0);
    let out = rt.analyze(&sizes, &sojourns, &idx, &thr).unwrap();

    assert_eq!(out.slowdowns.len(), n);
    assert_eq!(out.count as usize, n);
    let rust_mst = psbs::stats::mean(&sojourns);
    assert!((out.mst() - rust_mst).abs() / rust_mst < 1e-3);
    let rust_total: f64 = slow.iter().sum();
    let art_total: f64 = out.bin_sums.iter().sum();
    assert!(
        (rust_total - art_total).abs() / rust_total < 1e-3,
        "total slowdown: artifact {art_total} vs rust {rust_total}"
    );
    let counted: f64 = out.bin_counts.iter().sum();
    assert_eq!(counted as usize, n, "padding leaked into bin counts");
}

/// `gen_weibull_lognormal` produces samples whose moments match the
/// requested distributions across chunk boundaries.
#[test]
fn gen_weibull_lognormal_moments() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(123);
    let n = rt.manifest.batch * 2 + 777;
    let (samples, mults) = rt
        .gen_weibull_lognormal(&mut rng, n, 1.0, 2.0, 0.5)
        .unwrap();
    assert_eq!(samples.len(), n);
    assert_eq!(mults.len(), n);
    let mean_s = psbs::stats::mean(&samples);
    assert!((mean_s - 2.0).abs() < 0.05, "Weibull(1, 2) mean {mean_s}");
    let mut logs: Vec<f64> = mults.iter().map(|m| m.ln()).collect();
    logs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    assert!(logs[logs.len() / 2].abs() < 0.02, "log-normal median");
}

/// The compiled Pareto selector (params[3] = 1) must match the rust
/// `Pareto::icdf` on the same uniforms.
#[test]
fn workload_artifact_matches_rust_pareto() {
    use psbs::workload::dists::Pareto;
    let Some(rt) = runtime() else { return };
    let b = rt.manifest.batch;
    let mut rng = Rng::new(88);
    for alpha in [1.0, 2.0] {
        let p = if alpha > 1.0 { Pareto::unit_mean(alpha) } else { Pareto::new(1.0, alpha) };
        let u: Vec<f32> = (0..b).map(|_| rng.u01() as f32).collect();
        let halves = vec![0.5f32; b];
        let params = [alpha as f32, p.xm as f32, 0.5, 1.0];
        let (samples, _) = rt.gen_batch(&u, &halves, &halves, &params).unwrap();
        for i in (0..b).step_by(131) {
            let expect = p.icdf(u[i] as f64);
            let got = samples[i] as f64;
            assert!(
                (got - expect).abs() < 5e-3 * expect.abs().max(1e-3),
                "alpha {alpha} i {i}: artifact {got} vs rust {expect}"
            );
        }
    }
}

/// `gen_pareto_lognormal` chunks correctly and respects the x_m bound.
#[test]
fn gen_pareto_lognormal_bounds_and_moments() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(89);
    let n = rt.manifest.batch + 99;
    let (samples, mults) = rt.gen_pareto_lognormal(&mut rng, n, 2.0, 0.5, 0.5).unwrap();
    assert_eq!(samples.len(), n);
    assert!(samples.iter().all(|&s| s >= 0.5 * (1.0 - 1e-5)), "Pareto >= xm");
    // mean = alpha*xm/(alpha-1) = 1 (heavy tail: loose tolerance).
    let mean = psbs::stats::mean(&samples);
    assert!((mean - 1.0).abs() < 0.1, "mean {mean}");
    assert!(mults.iter().all(|&m| m > 0.0));
}

/// The full workload-generation path through the artifact yields the
/// same qualitative scheduling results as the pure-rust path (MST
/// ratios within a few percent on the default workload).
#[test]
fn artifact_workload_statistically_equivalent() {
    let Some(rt) = runtime() else { return };
    let njobs = 5_000;
    let shape = 0.5; // moderate tail: MST stable enough to compare
    let sigma = 0.5;

    // Artifact path.
    let rng = Rng::new(2024);
    let scale = 1.0 / psbs::stats::gamma(1.0 + 1.0 / shape);
    let (sizes, mults) = rt
        .gen_weibull_lognormal(&mut rng.substream(1), njobs, shape, scale, sigma)
        .unwrap();
    let gap_scale = Weibull::with_mean(1.0, 1.0 / 0.9).scale;
    let (gaps, _) = rt
        .gen_weibull_lognormal(&mut rng.substream(2), njobs, 1.0, gap_scale, 0.0)
        .unwrap();
    let mut t = 0.0;
    let art_jobs: Vec<Job> = (0..njobs)
        .map(|i| {
            t += gaps[i];
            let size = sizes[i].max(1e-9);
            Job {
                id: i as u32,
                arrival: t,
                size,
                est: (size * mults[i]).max(1e-9),
                weight: 1.0,
            }
        })
        .collect();

    // Pure-rust path (different stream, same distributions).
    let cfg = psbs::workload::SynthConfig::default()
        .with_shape(shape)
        .with_njobs(njobs);
    let rust_jobs = psbs::workload::synthesize(&cfg, 2024);

    // Compare the PS-normalized PSBS ratio — a distributional property.
    let ratio = |jobs: &[Job]| {
        let mut a = psbs::sched::by_name("psbs").unwrap();
        let pa = psbs::sim::run(a.as_mut(), jobs).mst(jobs);
        let mut b = psbs::sched::by_name("ps").unwrap();
        let pb = psbs::sim::run(b.as_mut(), jobs).mst(jobs);
        pa / pb
    };
    let ra = ratio(&art_jobs);
    let rb = ratio(&rust_jobs);
    assert!(
        (ra - rb).abs() < 0.25,
        "artifact-generated ratio {ra} vs rust-generated {rb}"
    );
    // And the headline must hold on both: PSBS beats PS here.
    assert!(ra < 1.0 && rb < 1.0, "psbs/ps ratios: artifact {ra}, rust {rb}");
}

/// LogNormal icdf vs the kernel's Box–Muller: distributional agreement
/// via a KS-style max-gap test on the empirical CDF.
#[test]
fn lognormal_ks_agreement() {
    let Some(rt) = runtime() else { return };
    let b = rt.manifest.batch;
    let sigma = 1.0;
    let mut rng = Rng::new(55);
    let u: Vec<f32> = (0..b).map(|_| rng.u01() as f32).collect();
    let ua: Vec<f32> = (0..b).map(|_| rng.u01() as f32).collect();
    let ub: Vec<f32> = (0..b).map(|_| rng.u01() as f32).collect();
    let (_, mults) = rt.gen_batch(&u, &ua, &ub, &[0.25, 1.0, sigma as f32, 0.0]).unwrap();
    let dist = LogNormal::error_model(sigma);
    let mut xs: Vec<f64> = mults.iter().map(|&m| m as f64).collect();
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut max_gap: f64 = 0.0;
    for q in 1..100 {
        let p = q as f64 / 100.0;
        let emp = psbs::stats::quantile_sorted(&xs, p);
        let theo = dist.icdf(p);
        // Compare in log space (multiplicative distribution).
        max_gap = max_gap.max((emp.ln() - theo.ln()).abs());
    }
    assert!(max_gap < 0.1, "quantile log-gap {max_gap}");
}
