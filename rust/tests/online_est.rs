//! Online estimate refinement — the differential pins.
//!
//! Three invariants anchor the `est(model=online,...)` layer:
//!
//! 1. **Frozen refinement is the static path, bit for bit.**
//!    `est(model=online,period=inf,sigma0=S,inner=P)` never refines, so
//!    it must reproduce `est(model=lognormal,sigma=S,inner=P)` exactly
//!    — same rng seeding, same draw per arrival, same schedule — for
//!    every discipline in the zoo.
//! 2. **Native re-key overrides are the cancel + re-admit default,
//!    bit for bit.**  `srpte`, the hybrid family and the FSP family
//!    override [`Scheduler::on_estimate_update`] with O(log n) in-place
//!    re-keys; forcing the trait-default body (cancel + re-admit)
//!    through the same refinement + kill churn must give bitwise-equal
//!    schedules.
//! 3. **The clamp is monotone.**  `JobStore::update_est` never stores
//!    an estimate below the row's attained service or the 1e-12 floor.

use psbs::coordinator::faults::FaultStats;
use psbs::scenario::PolicySpec;
use psbs::sched::{self, ALL_POLICIES};
use psbs::sim::{run, Completion, Job, JobId, JobStore, Scheduler};
use psbs::util::check::{property, Config};
use psbs::util::rng::Rng;
use psbs::workload::dists::{Dist, Weibull};
use psbs::workload::{synthesize, SynthConfig};

fn assert_bitwise(what: &str, want: &[f64], got: &[f64]) {
    assert_eq!(want.len(), got.len(), "{what}: length mismatch");
    for (i, (w, g)) in want.iter().zip(got).enumerate() {
        assert_eq!(
            w.to_bits(),
            g.to_bits(),
            "{what}: job {i} diverged ({w} vs {g})"
        );
    }
}

/// The headline pin: a never-refining online estimator is the static
/// log-normal wrapper, bitwise, across the whole policy zoo.
#[test]
fn online_period_inf_is_bit_identical_to_static_lognormal() {
    let jobs = synthesize(&SynthConfig::default().with_njobs(1_500), 11);
    for name in ALL_POLICIES {
        let frozen = PolicySpec::parse(&format!(
            "est(model=online,sigma0=1.5,period=inf,inner={name})"
        ))
        .unwrap();
        let static_ = PolicySpec::parse(&format!("est(model=lognormal,sigma=1.5,inner={name})"))
            .unwrap();
        let a = run(frozen.build_seeded(3).as_mut(), &jobs).completion;
        let b = run(static_.build_seeded(3).as_mut(), &jobs).completion;
        assert_bitwise(&format!("{name}: online(period=inf) vs static"), &b, &a);
    }
}

/// Forwarding wrapper that erases a discipline's native
/// `on_estimate_update` override and substitutes the trait-default
/// body (cancel + re-admit).  Everything else forwards untouched, so
/// any schedule difference against the bare discipline isolates the
/// override.
struct ForceReadmit(Box<dyn Scheduler>);

impl Scheduler for ForceReadmit {
    fn name(&self) -> &'static str {
        "force-readmit"
    }
    fn on_arrival(&mut self, now: f64, id: JobId, store: &JobStore) {
        self.0.on_arrival(now, id, store)
    }
    fn on_arrival_batch(&mut self, now: f64, ids: std::ops::Range<JobId>, store: &JobStore) {
        self.0.on_arrival_batch(now, ids, store)
    }
    fn next_event(&self, now: f64) -> Option<f64> {
        self.0.next_event(now)
    }
    fn advance(&mut self, now: f64, t: f64, store: &JobStore, done: &mut Vec<Completion>) {
        self.0.advance(now, t, store, done)
    }
    fn active(&self) -> usize {
        self.0.active()
    }
    fn cancel(&mut self, now: f64, id: u32) -> bool {
        self.0.cancel(now, id)
    }
    fn on_estimate_update(&mut self, now: f64, id: JobId, store: &JobStore) -> bool {
        // The trait-default body, forced even where the inner
        // discipline has a native override.
        if self.0.cancel(now, id) {
            self.0.on_arrival(now, id, store);
            true
        } else {
            false
        }
    }
    fn fault_stats(&self) -> Option<FaultStats> {
        self.0.fault_stats()
    }
}

fn random_jobs(rng: &mut Rng, size: usize) -> Vec<Job> {
    let n = 6 + size * 2;
    let w = Weibull::unit_mean(0.4 + rng.u01());
    let mut t = 0.0;
    (0..n as u32)
        .map(|i| {
            t += rng.u01();
            let s = w.sample(rng).max(1e-6);
            // `est` is overwritten by the refiner's initial draw; the
            // delivered value is irrelevant but kept realistic.
            Job { id: i, arrival: t, size: s, est: s, weight: 1.0 / (1.0 + rng.below(3) as f64) }
        })
        .collect()
}

/// Drive a scheduler through arrivals + a kill schedule (the
/// `tests/cancellation.rs` harness shape, policy-agnostic).  Returns
/// (completion, killed).
fn drive(s: &mut dyn Scheduler, jobs: &[Job], kills: &[(f64, u32)]) -> (Vec<f64>, Vec<bool>) {
    let mut store = JobStore::new();
    let mut completion = vec![f64::NAN; jobs.len()];
    let mut killed = vec![false; jobs.len()];
    let mut done = Vec::new();
    let mut now = 0.0_f64;
    let mut next = 0usize;
    let mut next_kill = 0usize;
    for _ in 0..200_000 {
        let mut t = f64::INFINITY;
        for cand in [
            jobs.get(next).map(|j| j.arrival),
            s.next_event(now),
            kills.get(next_kill).map(|&(k, _)| k),
        ]
        .into_iter()
        .flatten()
        {
            t = t.min(cand);
        }
        if !t.is_finite() {
            break;
        }
        let t = t.max(now);
        done.clear();
        s.advance(now, t, &store, &mut done);
        for c in &done {
            assert!(completion[c.id as usize].is_nan(), "job {} completed twice", c.id);
            assert!(!killed[c.id as usize], "killed job {} completed", c.id);
            completion[c.id as usize] = c.time;
        }
        now = t;
        while next_kill < kills.len() && kills[next_kill].0 <= now {
            let victim = kills[next_kill].1;
            if s.cancel(now, victim) {
                killed[victim as usize] = true;
            }
            next_kill += 1;
        }
        while next < jobs.len() && jobs[next].arrival <= now {
            let id = store.push(&jobs[next]);
            s.on_arrival(now, id, &store);
            next += 1;
        }
        if next == jobs.len() && next_kill == kills.len() && s.next_event(now).is_none() {
            break;
        }
    }
    assert_eq!(s.active(), 0, "active() must drain to 0");
    (completion, killed)
}

/// The override pin: for EVERY policy, refinement delivered through the
/// native `on_estimate_update` override equals refinement delivered
/// through the forced cancel + re-admit default — bitwise — under
/// random kill churn.  (Disciplines without an override compare the
/// default against itself; the heap-keyed and FSP-family natives are
/// the real subjects.)
#[test]
fn native_overrides_match_forced_readmit_under_churn() {
    property(
        "on_estimate_update native vs readmit",
        Config { cases: 12, max_size: 24, seed: 0x0E57 },
        |rng, size| {
            let jobs = random_jobs(rng, size);
            let span = jobs.last().unwrap().arrival + 4.0;
            let nkills = rng.below(1 + jobs.len() as u64 / 4) as usize;
            let mut kills: Vec<(f64, u32)> = (0..nkills)
                .map(|_| (rng.u01() * span, rng.below(jobs.len() as u64) as u32))
                .collect();
            kills.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            let period = 0.25 + rng.u01() * 2.0;
            let sigma0 = 0.5 + rng.u01() * 2.0;
            let decay = 0.5 + rng.u01() * 0.5;
            let seed = rng.below(1 << 20);
            (jobs, kills, period, sigma0, decay, seed)
        },
        |(jobs, kills, period, sigma0, decay, seed)| {
            for name in ALL_POLICIES {
                let native = &mut psbs::estimate::OnlineRefiner::new(
                    *sigma0,
                    *period,
                    *decay,
                    sched::by_name(name).unwrap(),
                    *seed,
                );
                let forced = &mut psbs::estimate::OnlineRefiner::new(
                    *sigma0,
                    *period,
                    *decay,
                    Box::new(ForceReadmit(sched::by_name(name).unwrap())),
                    *seed,
                );
                let (want, killed_a) = drive(forced, jobs, kills);
                let (got, killed_b) = drive(native, jobs, kills);
                if killed_a != killed_b {
                    return Err(format!("{name}: kill sets differ"));
                }
                for (i, (w, g)) in want.iter().zip(&got).enumerate() {
                    if w.to_bits() != g.to_bits() {
                        return Err(format!(
                            "{name}: job {i} diverged: readmit {w} vs native {g} \
                             (period={period}, sigma0={sigma0}, decay={decay})"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// The monotone-clamp property: whatever is written through
/// `JobStore::update_est`, the stored estimate is exactly
/// `max(value, attained, 1e-12)` — never below attained service, never
/// below the floor, and faithfully returned.
#[test]
fn update_est_monotone_clamp_property() {
    property(
        "update_est clamp",
        Config { cases: 48, max_size: 32, ..Default::default() },
        |rng, size| {
            let n = 2 + size;
            let sizes: Vec<f64> = (0..n).map(|_| rng.u01() * 10.0).collect();
            let ops: Vec<(u32, f64)> = (0..n * 3)
                .map(|_| (rng.below(n as u64) as u32, rng.u01() * 12.0 - 2.0))
                .collect();
            let complete: Vec<u32> =
                (0..n / 2).map(|_| rng.below(n as u64) as u32).collect();
            (sizes, ops, complete)
        },
        |(sizes, ops, complete)| {
            let mut store = JobStore::new();
            for (i, &s) in sizes.iter().enumerate() {
                store.push(&Job::exact(i as u32, 0.0, s.max(1e-9)));
            }
            for &id in complete {
                if store.is_active(id) {
                    store.mark_completed(id);
                }
            }
            for &(id, v) in ops {
                let attained = store.attained(id);
                let ret = store.update_est(id, v);
                let expect = v.max(attained).max(1e-12);
                if ret.to_bits() != expect.to_bits() || store.est(id).to_bits() != ret.to_bits() {
                    return Err(format!(
                        "update_est({id}, {v}) stored {ret}, expected {expect} \
                         (attained {attained})"
                    ));
                }
            }
            Ok(())
        },
    );
}
