//! Streaming-engine equivalence — the PR-7 headline invariant: the
//! O(active)-memory streaming loop ([`psbs::sim::run_streaming`]) is
//! *bit-identical* to the materialized [`psbs::sim::run`] for every
//! discipline in the zoo on random workloads, including the drain-mode
//! engine under fault injection and speculative kill churn.  Plus the
//! trace side: CSV rows survive a round-trip through the `.psbt`
//! binary cache exactly, the cached streaming replay produces the very
//! jobs `TraceFile::to_jobs` materializes, and corrupted caches fail
//! hard with distinct errors rather than replaying garbage.

use psbs::coordinator::{FaultConfig, FaultSpec, RetryPolicy};
use psbs::scenario::PolicySpec;
use psbs::sched;
use psbs::sim::{self, Completion, CompletionSink, Job, SliceSource, VirtualClock};
use psbs::util::check::{property, Config};
use psbs::util::rng::Rng;
use psbs::workload::cache::{write_cache, CacheReader};
use psbs::workload::dists::{Dist, LogNormal, Weibull};
use psbs::workload::trace_file::{parse, TraceFile, TraceJobSource};
use std::sync::Arc;

fn random_jobs(rng: &mut Rng, size: usize, sigma: f64) -> Vec<Job> {
    let n = 4 + size * 2;
    let w = Weibull::unit_mean(0.4 + rng.u01());
    let err = LogNormal::error_model(sigma);
    let mut t = 0.0;
    (0..n as u32)
        .map(|i| {
            t += rng.u01();
            let s = w.sample(rng).max(1e-6);
            Job {
                id: i,
                arrival: t,
                size: s,
                est: (s * err.sample(rng)).max(1e-9),
                weight: 1.0 / (1.0 + rng.below(3) as f64),
            }
        })
        .collect()
}

/// Sink that rebuilds the dense completion vector [`sim::run`] returns,
/// with the same completed-twice check the engine's own recorder has.
struct CollectSink {
    completion: Vec<f64>,
    arrivals: u64,
}

impl CollectSink {
    fn new(n: usize) -> CollectSink {
        CollectSink { completion: vec![f64::NAN; n], arrivals: 0 }
    }
}

impl CompletionSink for CollectSink {
    fn on_arrival(&mut self, _now: f64, _job: &Job) {
        self.arrivals += 1;
    }

    fn on_completion(&mut self, _time: f64, c: &Completion) {
        assert!(
            self.completion[c.id as usize].is_nan(),
            "job {} completed twice",
            c.id
        );
        self.completion[c.id as usize] = c.time;
    }
}

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// The headline property: for every `ALL_POLICIES` entry, streaming a
/// random workload through [`sim::run_streaming`] reproduces
/// [`sim::run`] bit-for-bit — completion times AND the internal event
/// counter, so the loops cannot have diverged even invisibly.
#[test]
fn run_streaming_is_bit_identical_to_run_all_policies() {
    property(
        "run_streaming == run (all policies)",
        Config { cases: 12, max_size: 16, seed: 0x57_EA_4 },
        |rng, size| random_jobs(rng, size, 0.5 + rng.u01() * 1.5),
        |jobs| {
            for policy in sched::ALL_POLICIES {
                let mut a = sched::by_name(policy).unwrap();
                let want = sim::run(a.as_mut(), jobs);

                let mut b = sched::by_name(policy).unwrap();
                let mut src = SliceSource::new(jobs);
                let mut sink = CollectSink::new(jobs.len());
                let stats = sim::run_streaming(b.as_mut(), &mut src, &mut sink);

                if bits(&sink.completion) != bits(&want.completion) {
                    return Err(format!("{policy}: completion times drifted"));
                }
                if stats.events != want.events {
                    return Err(format!(
                        "{policy}: events {} != {}",
                        stats.events, want.events
                    ));
                }
                if stats.delivered != jobs.len() as u64
                    || stats.completed != jobs.len() as u64
                    || sink.arrivals != jobs.len() as u64
                {
                    return Err(format!("{policy}: delivery accounting drifted: {stats:?}"));
                }

                // The PR 9 clock abstraction: the clock-generic entry
                // point driven by a VirtualClock must be the same loop
                // — completion bits and event counts included.
                let mut c = sched::by_name(policy).unwrap();
                let mut src = SliceSource::new(jobs);
                let mut sink = CollectSink::new(jobs.len());
                let stats = sim::run_streaming_clocked(
                    c.as_mut(),
                    &mut src,
                    &mut sink,
                    &mut VirtualClock,
                    true,
                );
                if bits(&sink.completion) != bits(&want.completion) {
                    return Err(format!("{policy}: clocked completion times drifted"));
                }
                if stats.events != want.events {
                    return Err(format!(
                        "{policy}: clocked events {} != {}",
                        stats.events, want.events
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Drain mode under fault injection (crashes, retries, losses) and
/// speculative kill churn: [`sim::run_streaming_to_drain`] must match
/// [`sim::run_to_drain`] bitwise — including which jobs never complete
/// (both leave NaN) and the full fault counter set.
#[test]
fn streaming_drain_matches_run_to_drain_under_fault_churn() {
    property(
        "streaming drain == drain (faults + speculation)",
        Config { cases: 10, max_size: 14, seed: 0xD4_A1 },
        |rng, size| {
            let jobs = random_jobs(rng, size, 1.2);
            let cfg = FaultConfig {
                spec: FaultSpec {
                    mtbf: 2.0 + rng.u01() * 20.0,
                    mttr: 0.2 + rng.u01() * 2.0,
                    slowdown: 0.25 + 0.75 * rng.u01(),
                },
                retry: RetryPolicy {
                    max_attempts: 1 + rng.below(4) as u32,
                    backoff: 0.5 * rng.u01(),
                },
                seed: rng.below(1 << 20),
            };
            let seed = rng.below(1 << 20);
            (jobs, cfg, seed)
        },
        |(jobs, cfg, seed)| {
            // Speculation (`speculate`) kills losing copies internally —
            // the kill-churn path — and the cluster crash plan retries
            // and loses jobs.
            for spec_str in [
                "psbs",
                "cluster(k=2,dispatch=leastwork,inner=psbs)",
                "speculate(after=2,inner=cluster(k=2,dispatch=jsq,inner=srpte))",
            ] {
                let spec = PolicySpec::from(spec_str);
                let mut a = spec.build_faulty(*seed, cfg);
                let want = sim::run_to_drain(a.as_mut(), jobs);
                let want_stats = a.fault_stats().unwrap_or_default();

                let mut b = spec.build_faulty(*seed, cfg);
                let mut src = SliceSource::new(jobs);
                let mut sink = CollectSink::new(jobs.len());
                let stats = sim::run_streaming_to_drain(b.as_mut(), &mut src, &mut sink);
                let got_stats = b.fault_stats().unwrap_or_default();

                if bits(&sink.completion) != bits(&want.completion) {
                    return Err(format!("{spec_str}: drain completion times drifted"));
                }
                if stats.events != want.events {
                    return Err(format!(
                        "{spec_str}: events {} != {}",
                        stats.events, want.events
                    ));
                }
                if want_stats != got_stats {
                    return Err(format!(
                        "{spec_str}: fault stats drifted: {want_stats:?} vs {got_stats:?}"
                    ));
                }

                // Clock-generic drain path under the same fault/kill
                // churn: VirtualClock must reproduce the pre-clock
                // drain engine bitwise, fault counters included.
                let mut c = spec.build_faulty(*seed, cfg);
                let mut src = SliceSource::new(jobs);
                let mut sink = CollectSink::new(jobs.len());
                let stats = sim::run_streaming_clocked(
                    c.as_mut(),
                    &mut src,
                    &mut sink,
                    &mut VirtualClock,
                    false,
                );
                if bits(&sink.completion) != bits(&want.completion) {
                    return Err(format!("{spec_str}: clocked drain completions drifted"));
                }
                if stats.events != want.events {
                    return Err(format!(
                        "{spec_str}: clocked drain events {} != {}",
                        stats.events, want.events
                    ));
                }
                if c.fault_stats().unwrap_or_default() != want_stats {
                    return Err(format!("{spec_str}: clocked drain fault stats drifted"));
                }
            }
            Ok(())
        },
    );
}

fn tmp_path(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("psbs_streaming_test");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// A deterministic, mildly heavy-tailed CSV trace with all optional
/// columns exercised (weights always, estimates on one variant).
fn sample_csv(with_est: bool) -> String {
    let mut text = String::from(if with_est {
        "arrival,size,weight,estimate\n"
    } else {
        "arrival,size,weight\n"
    });
    for i in 0..200u32 {
        let size = 1 + (i as u64 * 7919) % 97 + if i % 17 == 0 { 500 } else { 0 };
        let w = 1 + i % 3;
        if with_est {
            text.push_str(&format!("{}.25,{size},{w},{}\n", i, size + 1));
        } else {
            text.push_str(&format!("{}.25,{size},{w}\n", i));
        }
    }
    text
}

/// CSV rows -> binary cache -> rows: exact (bitwise f64) equality, and
/// the cached streaming replay yields the very jobs `to_jobs`
/// materializes from the CSV — so `replay --format bin` cannot drift
/// from `replay --format csv` on the same data.
#[test]
fn csv_to_cache_round_trip_is_exact() {
    for with_est in [false, true] {
        let rows = parse(&sample_csv(with_est)).unwrap();
        let path = tmp_path(&format!("round_trip_{with_est}.psbt"));
        let path_str = path.to_str().unwrap();
        let n = write_cache(path_str, rows.iter().copied()).unwrap();
        assert_eq!(n, rows.len() as u64);

        let mut reader = CacheReader::open(path_str).unwrap();
        assert_eq!(reader.len(), rows.len() as u64);
        use psbs::workload::trace_file::RowStream;
        let mut back = Vec::new();
        while let Some(r) = reader.next_row().unwrap() {
            back.push(r);
        }
        assert_eq!(back, rows, "cache round-trip drifted (with_est={with_est})");

        // Streamed jobs from the cache == materialized jobs from the CSV.
        for (sigma, seed) in [(0.0, 9_u64), (0.5, 9), (2.0, 23)] {
            let tf = TraceFile { path: "mem.csv".into(), rows: Arc::new(rows.clone()) };
            let want = tf.to_jobs(usize::MAX, 0.9, sigma, seed);
            let reader = CacheReader::open(path_str).unwrap();
            let mut src = TraceJobSource::new(reader, usize::MAX, 0.9, sigma, seed).unwrap();
            let mut got = Vec::new();
            while let Some(j) = psbs::sim::JobSource::next_job(&mut src) {
                got.push(j);
            }
            assert_eq!(got, want, "with_est={with_est} sigma={sigma} seed={seed}");
        }
        std::fs::remove_file(&path).ok();
    }
}

/// Corruption is a hard, *distinct* error at open time — never a
/// silent short or garbage replay: bad magic, unsupported version,
/// truncated payload, header/payload length mismatch, and a flipped
/// payload bit (checksum) each fail with their own message.
#[test]
fn corrupted_caches_fail_hard_and_distinctly() {
    let rows = parse(&sample_csv(false)).unwrap();
    let path = tmp_path("corrupt.psbt");
    let path_str = path.to_str().unwrap();
    write_cache(path_str, rows.iter().copied()).unwrap();
    let good = std::fs::read(&path).unwrap();

    let open_err = |bytes: &[u8]| -> String {
        std::fs::write(&path, bytes).unwrap();
        CacheReader::open(path_str).expect_err("corrupt cache must not open").to_string()
    };

    let mut bad_magic = good.clone();
    bad_magic[0] = b'X';
    assert!(open_err(&bad_magic).contains("bad magic"));

    let mut bad_version = good.clone();
    bad_version[4] = 99;
    assert!(open_err(&bad_version).contains("unsupported trace cache version"));

    let truncated = &good[..good.len() - 7];
    assert!(open_err(truncated).contains("truncated trace cache"));

    let header_only = &good[..10];
    assert!(open_err(header_only).contains("header"));

    let mut flipped = good.clone();
    let last = flipped.len() - 1;
    flipped[last] ^= 0x01;
    assert!(open_err(&flipped).contains("checksum mismatch"));

    std::fs::remove_file(&path).ok();
    assert!(CacheReader::open(path_str)
        .expect_err("missing file")
        .to_string()
        .contains("reading trace cache"));
}
