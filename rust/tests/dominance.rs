//! Property tests for the §3 dominance theorem.
//!
//! * Pri_S (over the completion sequence of a schedule) dominates that
//!   schedule: no job completes later — checked against PS, DPS, LAS
//!   and FIFO on random workloads;
//! * PSBS with exact sizes dominates DPS (the paper's §5.2 claim);
//! * FSP (PSBS, unit weights, exact sizes) dominates PS (Friedman &
//!   Henderson's original theorem).

use psbs::sched::{self, pri::Pri};
use psbs::sim::{self, Job};
use psbs::util::check::{property, Config};
use psbs::util::rng::Rng;
use psbs::workload::dists::{Dist, LogNormal, Weibull};

/// Random workload: heavy-ish Weibull sizes, exponential-ish gaps,
/// optional weights, optional estimation error.
fn random_jobs(rng: &mut Rng, size: usize, sigma: f64, weighted: bool) -> Vec<Job> {
    let n = 2 + size * 3;
    let w = Weibull::unit_mean(0.35 + rng.u01());
    let err = LogNormal::error_model(sigma);
    let mut t = 0.0;
    (0..n as u32)
        .map(|i| {
            t += rng.u01() * 1.5;
            let s = w.sample(rng).max(1e-6);
            let est = if sigma > 0.0 { (s * err.sample(rng)).max(1e-9) } else { s };
            let weight = if weighted { 1.0 / (1.0 + rng.below(5) as f64) } else { 1.0 };
            Job { id: i, arrival: t, size: s, est, weight }
        })
        .collect()
}

fn check_dominates(base_policy: &str, jobs: &[Job]) -> Result<(), String> {
    let mut base = sched::by_name(base_policy).unwrap();
    let base_res = sim::run(base.as_mut(), jobs);
    let mut pri = Pri::from_completions(&base_res.completion);
    let pri_res = sim::run(&mut pri, jobs);
    for i in 0..jobs.len() {
        if pri_res.completion[i] > base_res.completion[i] + 1e-6 {
            return Err(format!(
                "job {i}: Pri_S {} later than {base_policy} {}",
                pri_res.completion[i], base_res.completion[i]
            ));
        }
    }
    Ok(())
}

#[test]
fn pri_dominates_ps() {
    property(
        "pri-dominates-ps",
        Config::default(),
        |rng, size| random_jobs(rng, size, 0.0, false),
        |jobs| check_dominates("ps", jobs),
    );
}

#[test]
fn pri_dominates_dps() {
    property(
        "pri-dominates-dps",
        Config { seed: 11, ..Default::default() },
        |rng, size| random_jobs(rng, size, 0.0, true),
        |jobs| check_dominates("dps", jobs),
    );
}

#[test]
fn pri_dominates_las() {
    property(
        "pri-dominates-las",
        Config { seed: 13, ..Default::default() },
        |rng, size| random_jobs(rng, size, 0.0, false),
        |jobs| check_dominates("las", jobs),
    );
}

#[test]
fn pri_dominates_fifo() {
    property(
        "pri-dominates-fifo",
        Config { seed: 17, ..Default::default() },
        |rng, size| random_jobs(rng, size, 0.0, false),
        |jobs| check_dominates("fifo", jobs),
    );
}

/// §5.2: with exact sizes, PSBS (which equals Pri_S over the DPS
/// completion sequence, computed *online* via the virtual lag)
/// dominates DPS.
#[test]
fn psbs_dominates_dps_without_errors() {
    property(
        "psbs-dominates-dps",
        Config { cases: 96, ..Default::default() },
        |rng, size| random_jobs(rng, size, 0.0, true),
        |jobs| {
            let mut psbs = sched::by_name("psbs").unwrap();
            let p = sim::run(psbs.as_mut(), jobs);
            let mut dps = sched::by_name("dps").unwrap();
            let d = sim::run(dps.as_mut(), jobs);
            for i in 0..jobs.len() {
                if p.completion[i] > d.completion[i] + 1e-6 {
                    return Err(format!(
                        "job {i}: PSBS {} later than DPS {}",
                        p.completion[i], d.completion[i]
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Friedman–Henderson: FSP dominates PS (unit weights, exact sizes).
#[test]
fn fsp_dominates_ps_without_errors() {
    property(
        "fsp-dominates-ps",
        Config { cases: 96, seed: 23, ..Default::default() },
        |rng, size| random_jobs(rng, size, 0.0, false),
        |jobs| {
            let mut fsp = sched::by_name("fsp").unwrap();
            let f = sim::run(fsp.as_mut(), jobs);
            let mut ps = sched::by_name("ps").unwrap();
            let p = sim::run(ps.as_mut(), jobs);
            for i in 0..jobs.len() {
                if f.completion[i] > p.completion[i] + 1e-6 {
                    return Err(format!(
                        "job {i}: FSP {} later than PS {}",
                        f.completion[i], p.completion[i]
                    ));
                }
            }
            Ok(())
        },
    );
}

/// SRPT (exact sizes) attains the minimum MST across the whole zoo —
/// the optimality the figures normalize against.
#[test]
fn srpt_mst_is_minimal_across_zoo() {
    property(
        "srpt-optimality",
        Config { cases: 48, seed: 29, ..Default::default() },
        |rng, size| random_jobs(rng, size, 0.0, false),
        |jobs| {
            let mut srpt = sched::by_name("srpt").unwrap();
            let opt = sim::run(srpt.as_mut(), jobs).mst(jobs);
            for policy in ["fifo", "ps", "las", "fsp", "fspe+ps", "psbs"] {
                let mut s = sched::by_name(policy).unwrap();
                let mst = sim::run(s.as_mut(), jobs).mst(jobs);
                if opt > mst + 1e-6 {
                    return Err(format!("SRPT MST {opt} beaten by {policy} {mst}"));
                }
            }
            Ok(())
        },
    );
}

/// Dominance does NOT hold under estimation errors (the paper's whole
/// point) — demonstrate one concrete violation so the test suite pins
/// the boundary of the theorem, not just its interior.
#[test]
fn dominance_breaks_with_errors() {
    // Under-estimated large job goes late at t = 0.1; from then on PSBS
    // serves *only* the late set, so the small job J1 (not late until
    // t = 1.2) waits — under PS it would progress immediately.  Hand
    // computation: PSBS completes J1 at 3.2, PS at 2.2.
    let jobs = vec![
        Job { id: 0, arrival: 0.0, size: 10.0, est: 0.1, weight: 1.0 },
        Job { id: 1, arrival: 0.2, size: 1.0, est: 1.0, weight: 1.0 },
    ];
    let mut psbs = sched::by_name("psbs").unwrap();
    let p = sim::run(psbs.as_mut(), &jobs);
    let mut dps = sched::by_name("dps").unwrap();
    let d = sim::run(dps.as_mut(), &jobs);
    let violated = (0..jobs.len()).any(|i| p.completion[i] > d.completion[i] + 1e-9);
    assert!(violated, "expected some job later under errors: psbs {:?} dps {:?}", p.completion, d.completion);
}
