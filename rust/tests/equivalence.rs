//! Equivalence tests between disciplines that must coincide in
//! degenerate settings (paper §5.2: "in the absence of errors and when
//! all job weights are the same, PSBS is equivalent to FSP"; DPS(w=1)
//! ≡ PS; PSBS(w=1) ≡ FSPE+PS; the O(log n) PSBS matches the naive O(n)
//! FSP implementation job for job).

use psbs::sched;
use psbs::sim::{self, Job};
use psbs::util::check::{property, Config};
use psbs::util::rng::Rng;
use psbs::workload::dists::{Dist, LogNormal, Weibull};

fn random_jobs(rng: &mut Rng, size: usize, sigma: f64) -> Vec<Job> {
    let n = 2 + size * 3;
    let w = Weibull::unit_mean(0.3 + rng.u01());
    let err = LogNormal::error_model(sigma);
    let mut t = 0.0;
    (0..n as u32)
        .map(|i| {
            t += rng.u01() * 1.2;
            let s = w.sample(rng).max(1e-6);
            let est = if sigma > 0.0 { (s * err.sample(rng)).max(1e-9) } else { s };
            Job { id: i, arrival: t, size: s, est, weight: 1.0 }
        })
        .collect()
}

fn completions(policy: &str, jobs: &[Job]) -> Vec<f64> {
    let mut s = sched::by_name(policy).unwrap();
    sim::run(s.as_mut(), jobs).completion
}

fn assert_equal_schedules(a: &str, b: &str, jobs: &[Job], tol: f64) -> Result<(), String> {
    let ca = completions(a, jobs);
    let cb = completions(b, jobs);
    for i in 0..jobs.len() {
        if (ca[i] - cb[i]).abs() > tol {
            return Err(format!("job {i}: {a} {} vs {b} {}", ca[i], cb[i]));
        }
    }
    Ok(())
}

#[test]
fn dps_with_unit_weights_is_ps() {
    property(
        "dps==ps",
        Config::default(),
        |rng, size| random_jobs(rng, size, 0.0),
        |jobs| assert_equal_schedules("dps", "ps", jobs, 1e-6),
    );
}

#[test]
fn psbs_with_unit_weights_is_fspe_ps_under_errors() {
    property(
        "psbs==fspe+ps",
        Config { seed: 5, ..Default::default() },
        |rng, size| random_jobs(rng, size, 1.5),
        |jobs| assert_equal_schedules("psbs", "fspe+ps", jobs, 1e-6),
    );
}

#[test]
fn psbs_without_errors_is_fsp_naive() {
    // The O(log n) virtual-lag implementation must match the classic
    // O(n)-per-arrival FSP exactly when sizes are known.
    property(
        "psbs==fsp-naive (exact)",
        Config { seed: 7, ..Default::default() },
        |rng, size| random_jobs(rng, size, 0.0),
        |jobs| assert_equal_schedules("psbs", "fsp-naive", jobs, 1e-6),
    );
}

#[test]
fn fspe_matches_fsp_naive_under_errors() {
    // Both implement §4.2 FSPE semantics (serial late jobs).
    property(
        "fspe==fsp-naive (errors)",
        Config { seed: 9, ..Default::default() },
        |rng, size| random_jobs(rng, size, 1.0),
        |jobs| assert_equal_schedules("fspe", "fsp-naive", jobs, 1e-6),
    );
}

#[test]
fn srpt_equals_srpte_with_exact_estimates() {
    property(
        "srpt==srpte (exact)",
        Config { seed: 11, ..Default::default() },
        |rng, size| random_jobs(rng, size, 0.0),
        |jobs| assert_equal_schedules("srpt", "srpte", jobs, 1e-9),
    );
}

#[test]
fn hybrid_schedulers_equal_bases_without_errors() {
    // §5.1: "in the absence of errors ... these scheduling policies
    // will be equivalent to SRPT(E) and FSP(E)".
    property(
        "hybrids==bases (exact)",
        Config { seed: 13, cases: 48, ..Default::default() },
        |rng, size| random_jobs(rng, size, 0.0),
        |jobs| {
            assert_equal_schedules("srpte+ps", "srpt", jobs, 1e-6)?;
            assert_equal_schedules("srpte+las", "srpt", jobs, 1e-6)?;
            assert_equal_schedules("fspe+ps", "fspe", jobs, 1e-6)?;
            assert_equal_schedules("fspe+las", "fspe", jobs, 1e-6)
        },
    );
}

#[test]
fn overestimation_only_never_makes_jobs_late() {
    // §5.1: with only over-estimations jobs are never late, so the
    // amended schedulers equal their bases even with (over-)errors.
    property(
        "over-estimation keeps equivalence",
        Config { seed: 17, cases: 48, ..Default::default() },
        |rng, size| {
            let mut jobs = random_jobs(rng, size, 0.0);
            for j in jobs.iter_mut() {
                j.est = j.size * (1.0 + rng.u01() * 3.0); // over-estimate
            }
            jobs
        },
        |jobs| {
            assert_equal_schedules("fspe+ps", "fspe", jobs, 1e-6)?;
            assert_equal_schedules("psbs", "fspe", jobs, 1e-6)
        },
    );
}

/// Work conservation: every discipline finishes all jobs at the same
/// last-completion time on a busy period (Σ service = Σ size).
#[test]
fn all_policies_work_conserving() {
    property(
        "work conservation",
        Config { seed: 19, cases: 32, ..Default::default() },
        |rng, size| random_jobs(rng, size, 1.0),
        |jobs| {
            // Keep one busy period: all jobs arrive at 0.
            let jobs: Vec<Job> =
                jobs.iter().map(|j| Job { arrival: 0.0, ..*j }).collect();
            let total: f64 = jobs.iter().map(|j| j.size).sum();
            for policy in sched::ALL_POLICIES {
                let last = completions(policy, &jobs)
                    .iter()
                    .cloned()
                    .fold(0.0, f64::max);
                if (last - total).abs() > 1e-6 * total.max(1.0) {
                    return Err(format!(
                        "{policy}: last completion {last} != total work {total}"
                    ));
                }
            }
            Ok(())
        },
    );
}

/// No completion can precede arrival + size under any policy.
#[test]
fn completions_respect_physics() {
    property(
        "completion >= arrival + size",
        Config { seed: 23, cases: 32, ..Default::default() },
        |rng, size| random_jobs(rng, size, 2.0),
        |jobs| {
            for policy in sched::ALL_POLICIES {
                let c = completions(policy, jobs);
                for (j, &ci) in jobs.iter().zip(&c) {
                    if ci + 1e-9 < j.arrival + j.size {
                        return Err(format!(
                            "{policy}: job {} done at {ci} before arrival {} + size {}",
                            j.id, j.arrival, j.size
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}
