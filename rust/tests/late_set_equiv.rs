//! Old-path vs `late_set` equivalence — the refactor pin.
//!
//! PR 5 moved the FSP family's late set and the SRPTE hybrids'
//! eligible pool from flat O(|L|)-per-event scans onto the shared
//! O(log |L|) [`psbs::sched::late_set::LateSet`] engine.  This file
//! keeps the *old* implementations alive verbatim (flat `VecDeque`
//! with per-job rate folds — the pre-refactor code, preserved here as
//! reference oracles) and pins the new path to them: completions must
//! agree to ≤ 1e-9 on randomized underestimated / weighted /
//! heavy-tailed workloads across all four late modes and both hybrid
//! share modes.  The independent `sim::smallstep` cross-validation in
//! `rust/tests/crossval.rs` covers the same disciplines from the
//! paper's definitions; this file covers them from the repo's own
//! previous implementation, so a behavior change cannot hide behind
//! the oracle's O(dt) tolerance.

use psbs::sched::{self, MinHeap};
use psbs::sim::{self, Completion, Job, JobId, JobStore, Scheduler};
use psbs::util::rng::Rng;
use psbs::util::EPS;
use psbs::workload::dists::{Dist, LogNormal, Weibull};
use std::collections::VecDeque;

// ---------------------------------------------------------------------------
// Reference #1: the pre-refactor FSP family (flat late VecDeque).
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum RefLateMode {
    Serial,
    Ps,
    Las,
    Dps,
}

#[derive(Debug, Clone, Copy)]
struct RefLateJob {
    id: u32,
    weight: f64,
    true_rem: f64,
    size: f64,
}

impl RefLateJob {
    fn attained(&self) -> f64 {
        self.size - self.true_rem
    }
}

#[derive(Debug, Clone, Copy)]
struct RefOJob {
    weight: f64,
    true_rem: f64,
    size: f64,
}

struct RefFspFamily {
    late_mode: RefLateMode,
    use_weights: bool,
    g: f64,
    w_v: f64,
    w_l: f64,
    o: MinHeap<RefOJob>,
    e: MinHeap<f64>,
    late: VecDeque<RefLateJob>,
}

impl RefFspFamily {
    fn with(late_mode: RefLateMode, use_weights: bool) -> Self {
        RefFspFamily {
            late_mode,
            use_weights,
            g: 0.0,
            w_v: 0.0,
            w_l: 0.0,
            o: MinHeap::with_dense_index(),
            e: MinHeap::new(),
            late: VecDeque::new(),
        }
    }

    fn weight_of(&self, job: &Job) -> f64 {
        if self.use_weights {
            job.weight
        } else {
            1.0
        }
    }

    fn next_virtual_completion(&self, now: f64) -> Option<f64> {
        let g_o = self.o.peek().map(|(g, _, _)| g);
        let g_e = self.e.peek().map(|(g, _, _)| g);
        let g_hat = match (g_o, g_e) {
            (None, None) => return None,
            (Some(a), None) => a,
            (None, Some(b)) => b,
            (Some(a), Some(b)) => a.min(b),
        };
        Some(now + ((g_hat - self.g) * self.w_v).max(0.0))
    }

    fn late_rate(&self, i: usize, las_group: (f64, f64)) -> f64 {
        match self.late_mode {
            RefLateMode::Serial => {
                if i == 0 {
                    1.0
                } else {
                    0.0
                }
            }
            RefLateMode::Ps => 1.0 / self.late.len() as f64,
            RefLateMode::Dps => self.late[i].weight / self.w_l,
            RefLateMode::Las => {
                let (min_att, k) = las_group;
                if self.late[i].attained() <= min_att + EPS {
                    1.0 / k
                } else {
                    0.0
                }
            }
        }
    }

    fn las_group(&self) -> (f64, f64) {
        if self.late_mode != RefLateMode::Las {
            return (0.0, 1.0);
        }
        let min_att = self
            .late
            .iter()
            .map(|l| l.attained())
            .fold(f64::INFINITY, f64::min);
        let k = self
            .late
            .iter()
            .filter(|l| l.attained() <= min_att + EPS)
            .count() as f64;
        (min_att, k)
    }

    fn drain_virtual_completions(&mut self) {
        loop {
            let g_o = self.o.peek().map(|(g, _, _)| g);
            let g_e = self.e.peek().map(|(g, _, _)| g);
            let (g_hat, from_o) = match (g_o, g_e) {
                (None, None) => return,
                (Some(a), None) => (a, true),
                (None, Some(b)) => (b, false),
                (Some(a), Some(b)) => {
                    if a <= b {
                        (a, true)
                    } else {
                        (b, false)
                    }
                }
            };
            if (g_hat - self.g) * self.w_v > EPS {
                return;
            }
            if from_o {
                let (_, id, oj) = self.o.pop().unwrap();
                self.w_v -= oj.weight;
                self.w_l += oj.weight;
                self.late.push_back(RefLateJob {
                    id: id as u32,
                    weight: oj.weight,
                    true_rem: oj.true_rem,
                    size: oj.size,
                });
            } else {
                let (_, _, w) = self.e.pop().unwrap();
                self.w_v -= w;
            }
            if self.o.is_empty() && self.e.is_empty() {
                self.w_v = 0.0;
            }
        }
    }
}

impl Scheduler for RefFspFamily {
    fn name(&self) -> &'static str {
        "ref-fsp-family"
    }

    fn on_arrival(&mut self, _now: f64, id: JobId, store: &JobStore) {
        let job = store.job(id);
        let w = self.weight_of(&job);
        let g_i = self.g + job.est / w;
        self.o
            .push(g_i, id as u64, RefOJob { weight: w, true_rem: job.size, size: job.size });
        self.w_v += w;
    }

    fn next_event(&self, now: f64) -> Option<f64> {
        let mut dt = f64::INFINITY;
        if let Some(t_v) = self.next_virtual_completion(now) {
            dt = dt.min(t_v - now);
        }
        if self.late.is_empty() {
            if let Some((_, _, oj)) = self.o.peek() {
                dt = dt.min(oj.true_rem);
            }
        } else {
            let las_group = self.las_group();
            for i in 0..self.late.len() {
                let r = self.late_rate(i, las_group);
                if r > 0.0 {
                    dt = dt.min(self.late[i].true_rem / r);
                }
            }
            if self.late_mode == RefLateMode::Las && self.late.len() > 1 {
                let (min_att, k) = las_group;
                let next_att = self
                    .late
                    .iter()
                    .map(|l| l.attained())
                    .filter(|a| *a > min_att + EPS)
                    .fold(f64::INFINITY, f64::min);
                if next_att.is_finite() {
                    dt = dt.min((next_att - min_att) * k);
                }
            }
        }
        if dt.is_finite() {
            Some(now + dt.max(0.0))
        } else {
            None
        }
    }

    fn advance(&mut self, now: f64, t: f64, _store: &JobStore, done: &mut Vec<Completion>) {
        let dt = t - now;
        if self.late.is_empty() {
            let completed = match self.o.head_mut() {
                Some(oj) => {
                    oj.true_rem -= dt;
                    oj.true_rem <= EPS
                }
                None => false,
            };
            if completed {
                let (g_i, id, oj) = self.o.pop().unwrap();
                self.e.push(g_i, id, oj.weight);
                done.push(Completion { id: id as u32, time: t });
            }
        } else {
            let las_group = self.las_group();
            for i in 0..self.late.len() {
                let r = self.late_rate(i, las_group);
                self.late[i].true_rem -= r * dt;
            }
            let mut i = 0;
            while i < self.late.len() {
                if self.late[i].true_rem <= EPS {
                    let l = self.late.remove(i).unwrap();
                    self.w_l -= l.weight;
                    if self.late.is_empty() {
                        self.w_l = 0.0;
                    }
                    done.push(Completion { id: l.id, time: t });
                } else {
                    i += 1;
                }
            }
        }
        if self.w_v > 0.0 {
            self.g += dt / self.w_v;
        }
        self.drain_virtual_completions();
    }

    fn active(&self) -> usize {
        self.o.len() + self.late.len()
    }
}

// ---------------------------------------------------------------------------
// Reference #2: the pre-refactor SRPTE hybrid (flat late Vec).
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum RefShareMode {
    Ps,
    Las,
}

#[derive(Debug, Clone, Copy)]
struct RefElig {
    id: u32,
    est_rem: f64,
    true_rem: f64,
    size: f64,
}

impl RefElig {
    fn attained(&self) -> f64 {
        self.size - self.true_rem
    }
}

struct RefSrpteHybrid {
    mode: RefShareMode,
    slot: Option<RefElig>,
    late: Vec<RefElig>,
    waiting: MinHeap<(f64, f64)>,
}

#[derive(Debug, Clone, Copy)]
struct RefRateCtx {
    share: f64,
    min_att: f64,
    k: usize,
    slot_rate: f64,
}

fn ref_late_rate(ctx: RefRateCtx, attained: f64) -> f64 {
    if attained <= ctx.min_att + EPS {
        ctx.share
    } else {
        0.0
    }
}

impl RefSrpteHybrid {
    fn new(mode: RefShareMode) -> Self {
        RefSrpteHybrid { mode, slot: None, late: Vec::new(), waiting: MinHeap::new() }
    }

    fn pull_slot(&mut self) {
        if self.slot.is_none() {
            if let Some((est_rem, id, (true_rem, size))) = self.waiting.pop() {
                self.slot = Some(RefElig { id: id as u32, est_rem, true_rem, size });
            }
        }
    }

    fn rate_ctx(&self) -> RefRateCtx {
        let n_elig = self.late.len() + usize::from(self.slot.is_some());
        if n_elig == 0 {
            return RefRateCtx { share: 0.0, min_att: f64::INFINITY, k: 0, slot_rate: 0.0 };
        }
        match self.mode {
            RefShareMode::Ps => {
                let share = 1.0 / n_elig as f64;
                RefRateCtx {
                    share,
                    min_att: f64::INFINITY,
                    k: n_elig,
                    slot_rate: if self.slot.is_some() { share } else { 0.0 },
                }
            }
            RefShareMode::Las => {
                let slot_att = self.slot.map(|s| s.attained());
                let min_att = self
                    .late
                    .iter()
                    .map(|e| e.attained())
                    .chain(slot_att)
                    .fold(f64::INFINITY, f64::min);
                let in_group = |a: f64| a <= min_att + EPS;
                let k = self.late.iter().filter(|e| in_group(e.attained())).count()
                    + usize::from(slot_att.map_or(false, in_group));
                let share = 1.0 / k as f64;
                RefRateCtx {
                    share,
                    min_att,
                    k,
                    slot_rate: if slot_att.map_or(false, in_group) { share } else { 0.0 },
                }
            }
        }
    }
}

impl Scheduler for RefSrpteHybrid {
    fn name(&self) -> &'static str {
        "ref-srpte-hybrid"
    }

    fn on_arrival(&mut self, _now: f64, id: JobId, store: &JobStore) {
        let fresh = RefElig {
            id,
            est_rem: store.est(id),
            true_rem: store.size(id),
            size: store.size(id),
        };
        match self.slot {
            None => self.slot = Some(fresh),
            Some(cur) if fresh.est_rem < cur.est_rem => {
                self.waiting.push(cur.est_rem, cur.id as u64, (cur.true_rem, cur.size));
                self.slot = Some(fresh);
            }
            Some(_) => self.waiting.push(fresh.est_rem, id as u64, (fresh.size, fresh.size)),
        }
    }

    fn next_event(&self, now: f64) -> Option<f64> {
        let ctx = self.rate_ctx();
        let mut dt = f64::INFINITY;
        for e in &self.late {
            let r = ref_late_rate(ctx, e.attained());
            if r > 0.0 {
                dt = dt.min(e.true_rem / r);
            }
        }
        if let Some(s) = &self.slot {
            if ctx.slot_rate > 0.0 {
                dt = dt.min(s.true_rem / ctx.slot_rate);
                if s.est_rem > 0.0 {
                    dt = dt.min(s.est_rem / ctx.slot_rate);
                }
            }
        }
        if self.mode == RefShareMode::Las && ctx.k > 0 {
            let next_att = self
                .late
                .iter()
                .map(|e| e.attained())
                .chain(self.slot.map(|s| s.attained()))
                .filter(|a| *a > ctx.min_att + EPS)
                .fold(f64::INFINITY, f64::min);
            if next_att.is_finite() {
                dt = dt.min((next_att - ctx.min_att) * ctx.k as f64);
            }
        }
        if dt.is_finite() {
            Some(now + dt.max(0.0))
        } else {
            None
        }
    }

    fn advance(&mut self, now: f64, t: f64, _store: &JobStore, done: &mut Vec<Completion>) {
        let dt = t - now;
        let ctx = self.rate_ctx();
        for e in self.late.iter_mut() {
            let r = ref_late_rate(ctx, e.attained());
            e.true_rem -= r * dt;
            e.est_rem -= r * dt;
        }
        if let Some(s) = self.slot.as_mut() {
            s.true_rem -= ctx.slot_rate * dt;
            s.est_rem -= ctx.slot_rate * dt;
        }
        let mut i = 0;
        while i < self.late.len() {
            if self.late[i].true_rem <= EPS {
                let e = self.late.swap_remove(i);
                done.push(Completion { id: e.id, time: t });
            } else {
                i += 1;
            }
        }
        if let Some(s) = self.slot {
            if s.true_rem <= EPS {
                done.push(Completion { id: s.id, time: t });
                self.slot = None;
            } else if s.est_rem <= EPS {
                self.late.push(s);
                self.slot = None;
            }
        }
        self.pull_slot();
    }

    fn active(&self) -> usize {
        self.late.len() + self.waiting.len() + usize::from(self.slot.is_some())
    }
}

// ---------------------------------------------------------------------------
// The pin.
// ---------------------------------------------------------------------------

/// Workload knobs: Weibull shape (low = heavy-tailed), lognormal error
/// sigma, a multiplicative underestimation bias (< 1 biases estimates
/// low, growing |L|), and whether weights vary.
fn workload(
    seed: u64,
    n: u32,
    shape: f64,
    sigma: f64,
    under_bias: f64,
    weighted: bool,
) -> Vec<Job> {
    let mut rng = Rng::new(seed);
    let w = Weibull::unit_mean(shape);
    let err = LogNormal::error_model(sigma);
    let mut t = 0.0;
    (0..n)
        .map(|i| {
            t += rng.u01() * 0.4;
            let size = w.sample(&mut rng).max(1e-6);
            let est = (size * err.sample(&mut rng) * under_bias).max(1e-9);
            let weight = if weighted { 1.0 / (1.0 + rng.below(4) as f64) } else { 1.0 };
            Job { id: i, arrival: t, size, est, weight }
        })
        .collect()
}

fn assert_equiv(name: &str, jobs: &[Job], old: &mut dyn Scheduler, new: &mut dyn Scheduler) {
    let a = sim::run(old, jobs).completion;
    let b = sim::run(new, jobs).completion;
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert!(
            (x - y).abs() <= 1e-9,
            "{name}: job {i} diverged: old {x} vs late_set {y}"
        );
    }
    assert_eq!(old.active(), 0, "{name}: old path leaked jobs");
    assert_eq!(new.active(), 0, "{name}: late_set path leaked jobs");
}

/// All four FSP-family late modes, over underestimated + heavy-tailed
/// + weighted workloads (the |L|-grows regime).
#[test]
fn fsp_family_matches_old_flat_path() {
    // (name, reference late mode, use_weights, new-path factory)
    type NewMk = fn() -> psbs::sched::fsp_family::FspFamily;
    let cases: [(&str, RefLateMode, bool, NewMk); 4] = [
        ("fspe", RefLateMode::Serial, false, psbs::sched::fsp_family::FspFamily::fspe),
        ("fspe+ps", RefLateMode::Ps, false, psbs::sched::fsp_family::FspFamily::fspe_ps),
        ("fspe+las", RefLateMode::Las, false, psbs::sched::fsp_family::FspFamily::fspe_las),
        ("psbs", RefLateMode::Dps, true, psbs::sched::fsp_family::FspFamily::new),
    ];
    // (shape, sigma, under_bias, weighted): skewed sizes, heavy error,
    // strong underestimation, weighted classes.
    let grids = [
        (0.5, 1.0, 1.0, false),
        (0.25, 2.0, 0.3, false), // heavy tail + heavy underestimation
        (0.5, 1.5, 0.5, true),   // weighted + underestimated
        (1.0, 0.5, 1.0, true),
    ];
    for (name, ref_mode, use_weights, new_mk) in cases {
        for (g, &(shape, sigma, bias, weighted)) in grids.iter().enumerate() {
            for seed in 0..3u64 {
                let s = 1000 + seed * 7 + g as u64 * 131;
                let jobs = workload(s, 250, shape, sigma, bias, weighted);
                let mut old = RefFspFamily::with(ref_mode, use_weights);
                let mut new = new_mk();
                assert_equiv(
                    &format!("{name} grid {g} seed {seed}"),
                    &jobs,
                    &mut old,
                    &mut new,
                );
            }
        }
    }
}

/// Both SRPTE hybrid modes over the same workload grid.
#[test]
fn srpte_hybrids_match_old_flat_path() {
    let grids = [
        (0.5, 1.0, 1.0, false),
        (0.25, 2.0, 0.3, false),
        (0.5, 1.5, 0.5, true),
    ];
    for (name, ref_mode) in [("srpte+ps", RefShareMode::Ps), ("srpte+las", RefShareMode::Las)] {
        for (g, &(shape, sigma, bias, weighted)) in grids.iter().enumerate() {
            for seed in 0..3u64 {
                let jobs =
                    workload(9000 + seed * 13 + g as u64 * 57, 250, shape, sigma, bias, weighted);
                let mut old = RefSrpteHybrid::new(ref_mode);
                let mut new = sched::by_name(name).unwrap();
                assert_equiv(
                    &format!("{name} grid {g} seed {seed}"),
                    &jobs,
                    &mut old,
                    new.as_mut(),
                );
            }
        }
    }
}

/// Cancellation equivalence: killing the same set of jobs at the same
/// instants in both paths leaves identical survivor completions (the
/// reference gets the same `cancel` semantics bolted on for the test).
#[test]
fn cancellation_matches_old_flat_path() {
    struct RefWithCancel(RefFspFamily);
    impl Scheduler for RefWithCancel {
        fn name(&self) -> &'static str {
            "ref+cancel"
        }
        fn on_arrival(&mut self, now: f64, id: JobId, store: &JobStore) {
            self.0.on_arrival(now, id, store)
        }
        fn next_event(&self, now: f64) -> Option<f64> {
            self.0.next_event(now)
        }
        fn advance(&mut self, now: f64, t: f64, store: &JobStore, done: &mut Vec<Completion>) {
            self.0.advance(now, t, store, done)
        }
        fn active(&self) -> usize {
            self.0.active()
        }
        fn cancel(&mut self, _now: f64, id: u32) -> bool {
            // The old flat path: O(|L|) scan + O(|L|) removal.
            if let Some((g_i, seq, oj)) = self.0.o.remove_by_seq(id as u64) {
                self.0.e.push(g_i, seq, oj.weight);
                return true;
            }
            if let Some(pos) = self.0.late.iter().position(|l| l.id == id) {
                let l = self.0.late.remove(pos).unwrap();
                self.0.w_l -= l.weight;
                if self.0.late.is_empty() {
                    self.0.w_l = 0.0;
                }
                return true;
            }
            false
        }
    }

    let mut rng = Rng::new(77);
    for trial in 0..6 {
        let jobs = workload(500 + trial, 160, 0.3, 1.5, 0.4, true);
        let span = jobs.last().unwrap().arrival + 4.0;
        let kills: Vec<(f64, u32)> = (0..8)
            .map(|_| (rng.u01() * span, rng.below(jobs.len() as u64) as u32))
            .collect();
        let mut sorted = kills.clone();
        sorted.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());

        let run_killing = |s: &mut dyn Scheduler| -> Vec<f64> {
            let mut store = JobStore::new();
            let mut completion = vec![f64::NAN; jobs.len()];
            let mut done = Vec::new();
            let mut now = 0.0;
            let mut next = 0usize;
            let mut next_kill = 0usize;
            loop {
                let candidates = [
                    jobs.get(next).map(|j| j.arrival),
                    s.next_event(now),
                    sorted.get(next_kill).map(|&(t, _)| t),
                ];
                let mut t = f64::INFINITY;
                for cand in candidates.into_iter().flatten() {
                    t = t.min(cand);
                }
                if !t.is_finite() {
                    break;
                }
                let t = t.max(now);
                done.clear();
                s.advance(now, t, &store, &mut done);
                for c in &done {
                    completion[c.id as usize] = c.time;
                }
                now = t;
                while next_kill < sorted.len() && sorted[next_kill].0 <= now {
                    s.cancel(now, sorted[next_kill].1);
                    next_kill += 1;
                }
                while next < jobs.len() && jobs[next].arrival <= now {
                    let id = store.push(&jobs[next]);
                    s.on_arrival(now, id, &store);
                    next += 1;
                }
                if next == jobs.len() && next_kill == sorted.len() && s.next_event(now).is_none()
                {
                    break;
                }
            }
            completion
        };

        let old = run_killing(&mut RefWithCancel(RefFspFamily::with(RefLateMode::Dps, true)));
        let new = run_killing(&mut psbs::sched::fsp_family::FspFamily::new());
        for (i, (x, y)) in old.iter().zip(&new).enumerate() {
            let same = (x.is_nan() && y.is_nan()) || (x - y).abs() <= 1e-9;
            assert!(same, "trial {trial} job {i}: old {x} vs late_set {y}");
        }
    }
}
