//! Cross-validation of every event-driven discipline against the
//! independent small-step oracle (`sim::smallstep`), which integrates
//! the allocation functions ω(i,t) straight from the paper's
//! definitions.  Agreement validates the event-driven bookkeeping
//! (heaps, virtual lag, late sets, LAS levels) — the two code paths
//! share nothing.

use psbs::sched;
use psbs::sim::smallstep::{simulate, Policy};
use psbs::sim::{self, Job};
use psbs::util::check::{property, Config};
use psbs::util::rng::Rng;
use psbs::workload::dists::{Dist, LogNormal, Weibull};

const DT: f64 = 2e-4;
/// Small-step error is O(n·dt); workloads here are <= ~40 jobs.
const TOL: f64 = 0.05;

fn random_jobs(rng: &mut Rng, size: usize, sigma: f64, weighted: bool) -> Vec<Job> {
    let n = 2 + size.min(12) * 3; // keep the oracle tractable
    let w = Weibull::unit_mean(0.5 + rng.u01());
    let err = LogNormal::error_model(sigma);
    let mut t = 0.0;
    (0..n as u32)
        .map(|i| {
            t += rng.u01() * 1.2;
            // Keep sizes O(1) so fixed-step error stays small.
            let s = w.sample(rng).clamp(0.05, 8.0);
            let est = if sigma > 0.0 {
                (s * err.sample(rng)).clamp(0.01, 30.0)
            } else {
                s
            };
            let weight = if weighted { 1.0 / (1.0 + rng.below(4) as f64) } else { 1.0 };
            Job { id: i, arrival: t, size: s, est, weight }
        })
        .collect()
}

fn crossval(policy_name: &str, oracle: Policy, sigma: f64, weighted: bool, seed: u64) {
    property(
        &format!("crossval {policy_name}"),
        Config { cases: 24, max_size: 12, seed },
        |rng, size| random_jobs(rng, size, sigma, weighted),
        |jobs| {
            let mut s = sched::by_name(policy_name).unwrap();
            let event = sim::run(s.as_mut(), jobs).completion;
            // The oracle is O(dt)-accurate; a near-tie (two jobs whose
            // remaining real or virtual times cross within O(dt)) can
            // flip an ordering decision, producing a different — but
            // still discipline-valid — schedule.  Refining dt resolves
            // true ties toward the exact (event-driven) decision, while
            // a genuine semantic bug stays broken at every dt.
            let mut last_err = String::new();
            for dt in [DT, DT / 8.0, DT / 64.0] {
                let small = simulate(oracle, jobs, dt);
                match agrees(&event, &small) {
                    Ok(()) => return Ok(()),
                    Err(e) => last_err = format!("dt={dt}: {e}"),
                }
            }
            Err(last_err)
        },
    );
}

/// Per-job agreement, allowing identity swaps among jobs whose
/// completion times form a matching multiset (same machine timeline).
fn agrees(event: &[f64], small: &[f64]) -> Result<(), String> {
    let mut diff: Vec<usize> =
        (0..event.len()).filter(|&i| (event[i] - small[i]).abs() > TOL).collect();
    if diff.is_empty() {
        return Ok(());
    }
    let mut ev: Vec<f64> = diff.iter().map(|&i| event[i]).collect();
    let mut sm: Vec<f64> = diff.iter().map(|&i| small[i]).collect();
    ev.sort_by(|a, b| a.partial_cmp(b).unwrap());
    sm.sort_by(|a, b| a.partial_cmp(b).unwrap());
    for (a, b) in ev.iter().zip(&sm) {
        if (a - b).abs() > TOL {
            diff.truncate(8);
            return Err(format!(
                "jobs {diff:?}: event-driven {ev:?} vs small-step {sm:?}"
            ));
        }
    }
    Ok(())
}

#[test]
fn fifo_matches_oracle() {
    crossval("fifo", Policy::Fifo, 0.0, false, 1);
}

#[test]
fn ps_matches_oracle() {
    crossval("ps", Policy::Ps, 0.0, false, 2);
}

#[test]
fn dps_matches_oracle() {
    crossval("dps", Policy::Dps, 0.0, true, 3);
}

#[test]
fn las_matches_oracle() {
    crossval("las", Policy::Las, 0.0, false, 4);
}

#[test]
fn srpt_exact_matches_oracle() {
    crossval("srpt", Policy::Srpte, 0.0, false, 5);
}

#[test]
fn srpte_with_errors_matches_oracle() {
    crossval("srpte", Policy::Srpte, 1.0, false, 6);
}

#[test]
fn srpte_ps_matches_oracle() {
    crossval("srpte+ps", Policy::SrptePs, 1.0, false, 7);
}

#[test]
fn srpte_las_matches_oracle() {
    crossval("srpte+las", Policy::SrpteLas, 1.0, false, 8);
}

#[test]
fn fspe_matches_oracle() {
    crossval("fspe", Policy::Fspe, 1.0, false, 9);
}

#[test]
fn fspe_ps_matches_oracle() {
    crossval("fspe+ps", Policy::FspePs, 1.0, false, 10);
}

#[test]
fn fspe_las_matches_oracle() {
    crossval("fspe+las", Policy::FspeLas, 1.0, false, 11);
}

#[test]
fn psbs_exact_matches_oracle() {
    crossval("psbs", Policy::Psbs, 0.0, true, 12);
}

#[test]
fn psbs_with_errors_matches_oracle() {
    crossval("psbs", Policy::Psbs, 1.0, true, 13);
}

/// The arXiv:1403.5996 hard regime — heavy estimation error, so |L|
/// grows and the late-set engine (not the no-late fast path) carries
/// the schedule.  All four `LateMode`s against the oracle.
#[test]
fn late_modes_heavy_error_match_oracle() {
    crossval("fspe", Policy::Fspe, 2.0, false, 15);
    crossval("fspe+ps", Policy::FspePs, 2.0, false, 16);
    crossval("fspe+las", Policy::FspeLas, 2.0, false, 17);
    crossval("psbs", Policy::Psbs, 2.0, true, 18);
}

#[test]
fn fsp_naive_matches_oracle() {
    crossval("fsp-naive", Policy::Fspe, 1.0, false, 14);
}
