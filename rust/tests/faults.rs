//! Fault-injection robustness — the PR-6 headline invariant: under ANY
//! fault schedule every job completes exactly once (counting the
//! surviving speculative copy), is externally killed, or exhausts its
//! retries and is accounted lost — no double completions, no leaks —
//! for every discipline in the zoo.  Plus the standing oracle: an
//! empty `FaultPlan` leaves the committed scenarios bit-identical
//! through the planner share path.

use psbs::coordinator::{Cluster, Dispatch, FaultConfig, FaultSpec, RetryPolicy};
use psbs::scenario::{PolicySpec, Scenario, SweepParams};
use psbs::sched;
use psbs::sim::{Job, JobStore, Scheduler};
use psbs::util::check::{property, Config};
use psbs::util::rng::Rng;
use psbs::workload::dists::{Dist, LogNormal, Weibull};

fn random_jobs(rng: &mut Rng, size: usize, sigma: f64) -> Vec<Job> {
    let n = 4 + size * 2;
    let w = Weibull::unit_mean(0.4 + rng.u01());
    let err = LogNormal::error_model(sigma);
    let mut t = 0.0;
    (0..n as u32)
        .map(|i| {
            t += rng.u01();
            let s = w.sample(rng).max(1e-6);
            Job {
                id: i,
                arrival: t,
                size: s,
                est: (s * err.sample(rng)).max(1e-9),
                weight: 1.0 / (1.0 + rng.below(3) as f64),
            }
        })
        .collect()
}

/// Drive a fault-injected cluster manually through arrivals, its own
/// crash/recover/retry schedule, and an external kill schedule, then
/// check conservation: every arrival is either completed (exactly
/// once, never after an external kill), externally killed, or counted
/// in `FaultStats::lost` — and `active()` drains to 0.
#[allow(clippy::too_many_arguments)]
fn run_faulty_with_kills(
    policy: &str,
    k: usize,
    dispatch: Dispatch,
    spec_after: Option<f64>,
    cfg: &FaultConfig,
    jobs: &[Job],
    kills: &[(f64, u32)],
) -> Result<(), String> {
    let spec = PolicySpec::from(policy);
    let mut s = Cluster::from_spec_full(&spec, k, dispatch, &[], 11, Some(cfg), spec_after);
    let mut store = JobStore::new();
    let mut completion = vec![f64::NAN; jobs.len()];
    let mut killed = vec![false; jobs.len()];
    let mut done = Vec::new();
    let mut now = 0.0_f64;
    let mut next = 0usize;
    let mut next_kill = 0usize;
    // Generous progress bound: a hang here should fail loudly, not eat
    // the CI timeout.
    for _ in 0..200_000 {
        let next_arrival = jobs.get(next).map(|j| j.arrival);
        let next_internal = s.next_event(now);
        let kill_t = kills.get(next_kill).map(|&(t, _)| t);
        let mut t = f64::INFINITY;
        for cand in [next_arrival, next_internal, kill_t].into_iter().flatten() {
            t = t.min(cand);
        }
        if !t.is_finite() {
            break;
        }
        let t = t.max(now);
        done.clear();
        s.advance(now, t, &store, &mut done);
        for c in &done {
            if !completion[c.id as usize].is_nan() {
                return Err(format!("{policy}: job {} completed twice", c.id));
            }
            if killed[c.id as usize] {
                return Err(format!("{policy}: externally killed job {} completed", c.id));
            }
            completion[c.id as usize] = c.time;
        }
        now = t;
        // Kills land before same-instant arrivals (leader-loop order).
        while next_kill < kills.len() && kills[next_kill].0 <= now {
            let victim = kills[next_kill].1;
            if s.cancel(now, victim) {
                if completion[victim as usize].is_finite() {
                    return Err(format!(
                        "{policy}: cancel({victim}) succeeded after completion"
                    ));
                }
                killed[victim as usize] = true;
            }
            next_kill += 1;
        }
        while next < jobs.len() && jobs[next].arrival <= now {
            let id = store.push(&jobs[next]);
            s.on_arrival(now, id, &store);
            next += 1;
        }
        if next == jobs.len() && next_kill == kills.len() && s.next_event(now).is_none() {
            break;
        }
    }
    if s.active() != 0 {
        return Err(format!("{policy}: active() = {} after drain", s.active()));
    }
    let stats = s.fault_stats().unwrap_or_default();
    let completed = completion.iter().filter(|c| c.is_finite()).count();
    let external = killed.iter().filter(|&&x| x).count();
    let lost = stats.lost as usize;
    if completed + external + lost != jobs.len() {
        return Err(format!(
            "{policy}: conservation violated: {completed} completed + {external} killed + \
             {lost} lost != {} arrivals (stats: {stats:?})",
            jobs.len()
        ));
    }
    Ok(())
}

/// The headline property: random fault plans x random external kill
/// schedules x every `ALL_POLICIES` entry (random k, dispatch, and an
/// occasional speculation threshold).
#[test]
fn fault_churn_conservation_all_policies() {
    property(
        "fault churn conservation (all policies)",
        Config { cases: 14, max_size: 16, seed: 0xFA_17 },
        |rng, size| {
            let jobs = random_jobs(rng, size, 1.2);
            let span = jobs.last().unwrap().arrival + 4.0;
            let nkills = rng.below(1 + jobs.len() as u64 / 4) as usize;
            let mut kills: Vec<(f64, u32)> = (0..nkills)
                .map(|_| (rng.u01() * span, rng.below(jobs.len() as u64) as u32))
                .collect();
            kills.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            let cfg = FaultConfig {
                spec: FaultSpec {
                    // Short enough (vs the ~span-length run) that
                    // crashes actually land mid-run.
                    mtbf: 2.0 + rng.u01() * 20.0,
                    mttr: 0.2 + rng.u01() * 2.0,
                    slowdown: 0.25 + 0.75 * rng.u01(),
                },
                retry: RetryPolicy {
                    max_attempts: 1 + rng.below(4) as u32,
                    backoff: 0.5 * rng.u01(),
                },
                seed: rng.below(1 << 20),
            };
            let k = 2 + rng.below(2) as usize;
            let dispatch = [
                Dispatch::RoundRobin,
                Dispatch::LeastWork,
                Dispatch::Random,
                Dispatch::Jsq,
                Dispatch::RandomD(2),
                Dispatch::LeastTime,
            ][rng.below(6) as usize];
            let spec_after = (rng.below(3) == 0).then(|| 1.5 + rng.u01() * 3.0);
            (jobs, kills, cfg, k, dispatch, spec_after)
        },
        |(jobs, kills, cfg, k, dispatch, spec_after)| {
            for policy in sched::ALL_POLICIES {
                run_faulty_with_kills(policy, *k, *dispatch, *spec_after, cfg, jobs, kills)?;
            }
            Ok(())
        },
    );
}

/// Regression pin: an *empty* `FaultPlan` attached to the committed
/// `fig6.toml` reproduces the fault-free sweep bit-identically through
/// the planner share path (the faulty build must collapse to the
/// original code paths), and its counter tables are identically zero.
#[test]
fn empty_fault_plan_reproduces_fig6_bitwise() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/scenarios/fig6.toml");
    let clean = Scenario::load(path).expect("load fig6.toml").with_njobs(150);
    let faulty = clean.clone().with_faults(FaultConfig::default());
    assert!(faulty.validate().is_ok(), "{:?}", faulty.validate());
    let p = SweepParams { reps: 1, seed: 42, converge: false };
    let tc = clean.tables(p, 2, true);
    let tf = faulty.tables(p, 2, true);
    let tf_mean: Vec<_> =
        tf.iter().filter(|t| !t.name.ends_with("_fault_counters")).collect();
    assert_eq!(tc.len(), tf_mean.len(), "one value table per split point either way");
    for (a, b) in tc.iter().zip(&tf_mean) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.header, b.header);
        assert_eq!(a.rows.len(), b.rows.len(), "table {}", a.name);
        for (ra, rb) in a.rows.iter().zip(&b.rows) {
            let ba: Vec<u64> = ra.iter().map(|v| v.to_bits()).collect();
            let bb: Vec<u64> = rb.iter().map(|v| v.to_bits()).collect();
            assert_eq!(ba, bb, "table {} drifted under an empty fault plan", a.name);
        }
    }
    let counters: Vec<_> =
        tf.iter().filter(|t| t.name.ends_with("_fault_counters")).collect();
    assert_eq!(counters.len(), tc.len(), "one counter table per value table");
    for t in counters {
        for row in &t.rows {
            assert!(
                row[1..].iter().all(|&v| v == 0.0),
                "table {}: empty fault plan produced non-zero counters: {row:?}",
                t.name
            );
        }
    }
}
