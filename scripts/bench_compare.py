#!/usr/bin/env python3
"""Diff two BENCH_*.json perf reports (schema psbs-bench-v1).

Usage:
    bench_compare.py BASELINE.json CURRENT.json
        [--threshold 0.20]
        [--keys planner_speedup_,dense_vs_map_]
        [--summary FILE]

Compares the `derived` scalars of two reports produced by the
dependency-free bench harness (rust/src/util/bench.rs; schema in
rust/benches/README.md).  Derived keys are ratios where HIGHER IS
BETTER (thread speedups, planner-vs-per-cell wins, dense-vs-map index
wins), so a REGRESSION is `current < baseline * (1 - threshold)`.

Only keys matching one of the --keys prefixes AND present in BOTH
files gate the exit code (default prefixes: the ROADMAP-tracked
`planner_speedup_*`, `dense_vs_map_*`, the streaming engine's
`stream_throughput_*` jobs/s, and the batched event loop's
`batch_event_speedup` — one coalesced `on_arrival_batch` call per
same-instant burst vs per-job dispatch, where a drop below ~1 means
batching started losing to the loop it replaced).  Everything else — other derived keys
(e.g. `trace_parse_throughput`, the late-set engine's
`late_set_*_scaling` population ratios, `fault_replay_overhead` and
`stream_vs_vec_overhead`, where ~1 is good and the "higher is better"
framing does not apply, `trace_cache_speedup`, and
`est_update_native_speedup` — the serving-slot win of the native
`on_estimate_update` override over its cancel+readmit default —
tracked but not gated) and per-sample mean_ns deltas — is reported
informationally.
Exits 1 on any gated regression, 0 otherwise; missing baselines are
not failures (first run on a branch has nothing to compare against).

stdlib-only by design: CI and offline containers run it bare.
"""

import argparse
import json
import sys

DEFAULT_KEY_PREFIXES = "planner_speedup_,dense_vs_map_,stream_throughput_,batch_event_speedup"


def load(path):
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") != "psbs-bench-v1":
        sys.exit(f"error: {path}: unexpected schema {doc.get('schema')!r}")
    return doc


def fmt_ratio(cur, base):
    """Relative change current vs baseline, e.g. -25.0 %."""
    if base == 0:
        return "n/a"
    return f"{cur / base - 1.0:+.1%}".replace("%", " %")


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline")
    ap.add_argument("current")
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.20,
        help="gated relative regression tolerance (default 0.20 = 20%%)",
    )
    ap.add_argument(
        "--keys",
        default=DEFAULT_KEY_PREFIXES,
        help="comma-separated derived-key prefixes that gate the exit code",
    )
    ap.add_argument(
        "--summary",
        default=None,
        help="append a markdown summary to this file (e.g. $GITHUB_STEP_SUMMARY)",
    )
    args = ap.parse_args()

    base = load(args.baseline)
    cur = load(args.current)
    prefixes = [p for p in args.keys.split(",") if p]

    base_derived = base.get("derived", {}) or {}
    cur_derived = cur.get("derived", {}) or {}
    shared = sorted(set(base_derived) & set(cur_derived))

    lines = [
        f"### bench compare: `{base.get('bench', '?')}`",
        "",
        f"baseline `{args.baseline}` vs current `{args.current}` "
        f"(gate: >{args.threshold:.0%} drop on {', '.join(prefixes)})",
        "",
        "| derived key | baseline | current | delta | gated | verdict |",
        "|---|---:|---:|---:|:--:|:--:|",
    ]
    regressions = []
    for key in shared:
        b, c = float(base_derived[key]), float(cur_derived[key])
        gated = any(key.startswith(p) for p in prefixes)
        regressed = gated and b > 0 and c < b * (1.0 - args.threshold)
        if regressed:
            regressions.append(key)
        verdict = "REGRESSED" if regressed else "ok"
        lines.append(
            f"| `{key}` | {b:.3f} | {c:.3f} | {fmt_ratio(c, b)} "
            f"| {'yes' if gated else 'no'} | {verdict} |"
        )
    if not shared:
        lines.append("| _no shared derived keys_ | | | | | |")

    # Informational: per-sample wall-clock deltas (lower is better).
    base_samples = {s["name"]: s for s in base.get("samples", [])}
    cur_samples = {s["name"]: s for s in cur.get("samples", [])}
    shared_samples = sorted(set(base_samples) & set(cur_samples))
    if shared_samples:
        lines += [
            "",
            "<details><summary>per-sample mean_ns (informational)</summary>",
            "",
            "| sample | baseline ns | current ns | delta |",
            "|---|---:|---:|---:|",
        ]
        for name in shared_samples:
            b = float(base_samples[name]["mean_ns"])
            c = float(cur_samples[name]["mean_ns"])
            lines.append(f"| `{name}` | {b:.0f} | {c:.0f} | {fmt_ratio(c, b)} |")
        lines += ["", "</details>"]

    if regressions:
        lines += ["", f"**{len(regressions)} gated regression(s): {', '.join(regressions)}**"]
    else:
        lines += ["", "no gated regressions"]

    report = "\n".join(lines) + "\n"
    print(report)
    if args.summary:
        with open(args.summary, "a") as f:
            f.write(report)

    sys.exit(1 if regressions else 0)


if __name__ == "__main__":
    main()
