#!/usr/bin/env python3
"""Unit tests for scripts/bench_compare.py (stdlib only — CI runs this
even on runners without a cargo toolchain, so the perf-gate logic is
tested independently of the rust build).

Covers the contract the CI bench-compare step relies on:
  * a >threshold drop on a gated derived key (planner_speedup_*,
    dense_vs_map_*, stream_throughput_*, batch_event_speedup) exits 1
    and is labelled REGRESSED;
  * drops within the threshold, drops on non-gated keys (e.g.
    trace_parse_throughput), and improvements exit 0;
  * keys missing from either file never gate;
  * --summary appends a markdown report;
  * a wrong schema is rejected.

Run: python3 scripts/test_bench_compare.py -v
"""

import json
import os
import subprocess
import sys
import tempfile
import unittest

SCRIPT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "bench_compare.py")


def report(derived, samples=(), schema="psbs-bench-v1"):
    return {
        "schema": schema,
        "bench": "sweeps",
        "samples": [
            {
                "name": name,
                "iters": 3,
                "mean_ns": mean_ns,
                "stddev_ns": 0.0,
                "min_ns": mean_ns,
                "items_per_iter": None,
                "ops_per_sec": 0.0,
            }
            for (name, mean_ns) in samples
        ],
        "derived": derived,
    }


class BenchCompareTest(unittest.TestCase):
    def setUp(self):
        self.dir = tempfile.TemporaryDirectory()

    def tearDown(self):
        self.dir.cleanup()

    def write(self, name, doc):
        path = os.path.join(self.dir.name, name)
        with open(path, "w") as f:
            json.dump(doc, f)
        return path

    def run_compare(self, baseline, current, *extra):
        return subprocess.run(
            [sys.executable, SCRIPT, baseline, current, *extra],
            capture_output=True,
            text=True,
        )

    def test_gated_regression_exits_1(self):
        base = self.write("base.json", report({"planner_speedup_t4": 2.0}))
        cur = self.write("cur.json", report({"planner_speedup_t4": 1.5}))  # -25%
        r = self.run_compare(base, cur)
        self.assertEqual(r.returncode, 1, r.stdout + r.stderr)
        self.assertIn("REGRESSED", r.stdout)
        self.assertIn("1 gated regression(s): planner_speedup_t4", r.stdout)

    def test_drop_within_threshold_passes(self):
        base = self.write("base.json", report({"planner_speedup_t4": 2.0}))
        cur = self.write("cur.json", report({"planner_speedup_t4": 1.7}))  # -15%
        r = self.run_compare(base, cur)
        self.assertEqual(r.returncode, 0, r.stdout + r.stderr)
        self.assertIn("no gated regressions", r.stdout)

    def test_custom_threshold_tightens_the_gate(self):
        base = self.write("base.json", report({"dense_vs_map_event": 1.0}))
        cur = self.write("cur.json", report({"dense_vs_map_event": 0.9}))  # -10%
        self.assertEqual(self.run_compare(base, cur).returncode, 0)
        r = self.run_compare(base, cur, "--threshold", "0.05")
        self.assertEqual(r.returncode, 1, r.stdout + r.stderr)

    def test_non_gated_key_drop_is_informational(self):
        # trace_parse_throughput halves: reported, never gates.
        base = self.write(
            "base.json",
            report({"trace_parse_throughput": 4e6, "planner_speedup_t1": 1.8}),
        )
        cur = self.write(
            "cur.json",
            report({"trace_parse_throughput": 2e6, "planner_speedup_t1": 1.8}),
        )
        r = self.run_compare(base, cur)
        self.assertEqual(r.returncode, 0, r.stdout + r.stderr)
        self.assertIn("trace_parse_throughput", r.stdout)
        self.assertNotIn("REGRESSED", r.stdout)

    def test_late_set_keys_are_informational(self):
        # late_set_*_scaling are population-cost ratios (~1 is good);
        # they must be reported but never gate, in either direction.
        base = self.write(
            "base.json",
            report(
                {
                    "late_set_scan_scaling": 1.05,
                    "late_set_cancel_scaling": 1.4,
                    "planner_speedup_t4": 2.0,
                },
                samples=[("late_set/scan/las/n100000", 50.0)],
            ),
        )
        cur = self.write(
            "cur.json",
            report(
                {
                    "late_set_scan_scaling": 9.0,  # huge "drop" in ratio terms
                    "late_set_cancel_scaling": 0.2,
                    "planner_speedup_t4": 2.0,
                },
                samples=[("late_set/scan/las/n100000", 55.0)],
            ),
        )
        r = self.run_compare(base, cur)
        self.assertEqual(r.returncode, 0, r.stdout + r.stderr)
        self.assertIn("late_set_scan_scaling", r.stdout)
        self.assertIn("late_set/scan/las/n100000", r.stdout)
        self.assertNotIn("REGRESSED", r.stdout)

    def test_est_update_key_is_informational(self):
        # est_update_native_speedup (the native on_estimate_update
        # override's serving-slot win over the cancel+readmit default)
        # is tracked but never gates, in either direction.
        base = self.write(
            "base.json",
            report(
                {"est_update_native_speedup": 8.0, "planner_speedup_t4": 2.0},
                samples=[("est/update/native/srpte_slot/n100000", 30.0)],
            ),
        )
        cur = self.write(
            "cur.json",
            report(
                {"est_update_native_speedup": 1.1, "planner_speedup_t4": 2.0},
                samples=[("est/update/native/srpte_slot/n100000", 240.0)],
            ),
        )
        r = self.run_compare(base, cur)
        self.assertEqual(r.returncode, 0, r.stdout + r.stderr)
        self.assertIn("est_update_native_speedup", r.stdout)
        self.assertIn("est/update/native/srpte_slot/n100000", r.stdout)
        self.assertNotIn("REGRESSED", r.stdout)

    def test_stream_throughput_drop_gates(self):
        # The streaming engine's jobs/s is a first-class gated key: a
        # >20% drop fails the compare like a planner_speedup_* drop.
        base = self.write(
            "base.json", report({"stream_throughput_jobs_per_s": 4e6})
        )
        cur = self.write(
            "cur.json", report({"stream_throughput_jobs_per_s": 2.5e6})  # -37.5%
        )
        r = self.run_compare(base, cur)
        self.assertEqual(r.returncode, 1, r.stdout + r.stderr)
        self.assertIn("stream_throughput_jobs_per_s", r.stdout)
        self.assertIn("REGRESSED", r.stdout)
        # Within threshold: passes.
        cur_ok = self.write(
            "cur_ok.json", report({"stream_throughput_jobs_per_s": 3.5e6})  # -12.5%
        )
        self.assertEqual(self.run_compare(base, cur_ok).returncode, 0)

    def test_stream_ratio_keys_are_informational(self):
        # stream_vs_vec_overhead (~1 is good) and trace_cache_speedup
        # are tracked but never gate, in either direction.
        base = self.write(
            "base.json",
            report(
                {
                    "stream_vs_vec_overhead": 1.02,
                    "trace_cache_speedup": 6.0,
                    "stream_throughput_jobs_per_s": 4e6,
                }
            ),
        )
        cur = self.write(
            "cur.json",
            report(
                {
                    "stream_vs_vec_overhead": 5.0,  # huge "drop" in ratio terms
                    "trace_cache_speedup": 1.1,
                    "stream_throughput_jobs_per_s": 4e6,
                }
            ),
        )
        r = self.run_compare(base, cur)
        self.assertEqual(r.returncode, 0, r.stdout + r.stderr)
        self.assertIn("stream_vs_vec_overhead", r.stdout)
        self.assertIn("trace_cache_speedup", r.stdout)
        self.assertNotIn("REGRESSED", r.stdout)

    def test_batch_event_speedup_drop_gates(self):
        # The batched event loop's coalescing win is a first-class
        # gated key: dropping from 1.5x to 1.0x (-33%) fails the
        # compare, while staying within the threshold passes — so a
        # refactor that quietly degrades `on_arrival_batch` back to
        # per-job dispatch cost is caught in CI.
        base = self.write(
            "base.json",
            report({"batch_event_speedup": 1.5, "soa_event_ns": 400.0}),
        )
        cur = self.write(
            "cur.json",
            report({"batch_event_speedup": 1.0, "soa_event_ns": 900.0}),  # -33%
        )
        r = self.run_compare(base, cur)
        self.assertEqual(r.returncode, 1, r.stdout + r.stderr)
        self.assertIn("batch_event_speedup", r.stdout)
        self.assertIn("REGRESSED", r.stdout)
        # soa_event_ns is informational (absolute ns, lower is better —
        # the ratio gate's framing does not apply): reported, not gated.
        self.assertNotIn("1 gated regression(s): soa_event_ns", r.stdout)
        self.assertIn("1 gated regression(s): batch_event_speedup", r.stdout)
        # Within threshold: passes.
        cur_ok = self.write(
            "cur_ok.json",
            report({"batch_event_speedup": 1.35, "soa_event_ns": 400.0}),  # -10%
        )
        self.assertEqual(self.run_compare(base, cur_ok).returncode, 0)

    def test_keys_missing_from_either_side_never_gate(self):
        base = self.write("base.json", report({"planner_speedup_t4": 2.0}))
        cur = self.write("cur.json", report({"planner_speedup_t1": 0.1}))
        r = self.run_compare(base, cur)
        self.assertEqual(r.returncode, 0, r.stdout + r.stderr)
        self.assertIn("no shared derived keys", r.stdout)

    def test_improvement_passes_and_samples_are_reported(self):
        base = self.write(
            "base.json",
            report({"planner_speedup_t4": 2.0}, samples=[("sweep/trace_parse/rows50k", 2e7)]),
        )
        cur = self.write(
            "cur.json",
            report({"planner_speedup_t4": 3.0}, samples=[("sweep/trace_parse/rows50k", 1e7)]),
        )
        r = self.run_compare(base, cur)
        self.assertEqual(r.returncode, 0, r.stdout + r.stderr)
        self.assertIn("sweep/trace_parse/rows50k", r.stdout)

    def test_summary_file_is_appended(self):
        base = self.write("base.json", report({"planner_speedup_t4": 2.0}))
        cur = self.write("cur.json", report({"planner_speedup_t4": 1.0}))
        summary = os.path.join(self.dir.name, "summary.md")
        with open(summary, "w") as f:
            f.write("pre-existing\n")
        r = self.run_compare(base, cur, "--summary", summary)
        self.assertEqual(r.returncode, 1)
        with open(summary) as f:
            text = f.read()
        self.assertTrue(text.startswith("pre-existing\n"), "must append, not truncate")
        self.assertIn("REGRESSED", text)

    def test_wrong_schema_is_rejected(self):
        base = self.write("base.json", report({}, schema="not-a-bench"))
        cur = self.write("cur.json", report({}))
        r = self.run_compare(base, cur)
        self.assertNotEqual(r.returncode, 0)
        self.assertIn("unexpected schema", r.stderr)


if __name__ == "__main__":
    unittest.main()
