#!/usr/bin/env bash
# Tier-1 verify in one command: release build + full test suite +
# format/lint gates + a short (~10 s) bench smoke that refreshes the
# machine-readable BENCH_*.json perf reports (schema:
# rust/benches/README.md).
#
# fmt and clippy are skipped gracefully when the toolchain lacks the
# component (offline containers often ship bare rustc/cargo) and are
# ADVISORY here: their status lands in the JSON summary but does not
# flip the tier-1 exit code.  CI promotes both to HARD gates in
# dedicated jobs (.github/workflows/ci.yml), so locally-advisory never
# means unenforced.  Build, test and bench failures are fatal, as is a
# missing toolchain.  The last line is a one-line JSON pass/fail
# summary for machines.
#
# The streaming smoke (BLOCKING, runs even with --no-bench) generates
# a million-row binary trace cache and replays it through the
# streaming engine under a hard RSS ceiling, pinning the O(active)
# memory claim on every verify.
#
# Usage:
#   scripts/tier1.sh             # build + test + fmt + clippy + bench smoke + streaming smoke
#   scripts/tier1.sh --no-bench  # skip the bench smoke
set -uo pipefail
cd "$(dirname "$0")/.."

# A missing toolchain is a hard failure, not a quiet no-op: two PRs
# shipped unverified because `cargo`-not-found produced success-shaped
# output.  The JSON summary still prints so machines see WHY.
if ! command -v cargo >/dev/null 2>&1; then
  echo "tier1: cargo not found — cannot build, test or bench" >&2
  echo '{"tier1": "fail", "toolchain": "absent", "build": "skipped", "test": "skipped", "fmt": "skipped", "clippy": "skipped", "bench": "skipped", "streaming_smoke": "skipped", "serve_smoke": "skipped"}'
  exit 1
fi

BUILD=fail TEST=skipped FMT=skipped CLIPPY=skipped BENCH=skipped STREAM=skipped SERVE=skipped

if cargo build --release; then BUILD=ok; fi

if [[ "$BUILD" == ok ]]; then
  TEST=fail
  if cargo test -q; then TEST=ok; fi
fi

# Format gate: only when rustfmt is installed for this toolchain.
if cargo fmt --version >/dev/null 2>&1; then
  FMT=fail
  if cargo fmt --check; then FMT=ok; fi
else
  echo "tier1: rustfmt unavailable; skipping fmt gate"
fi

# Lint gate: only when clippy is installed; warnings are errors.
if cargo clippy --version >/dev/null 2>&1; then
  CLIPPY=fail
  if cargo clippy --all-targets -- -D warnings; then CLIPPY=ok; fi
else
  echo "tier1: clippy unavailable; skipping lint gate"
fi

# Streaming smoke (BLOCKING): generate a million-row binary trace
# cache and replay it through the O(active)-memory streaming engine
# with a hard RSS ceiling — the headline PR-7 claim ("10^6-job run in
# bounded memory") verified on every tier-1 run, not just asserted.
# 300 MB is ~10x headroom over the measured footprint yet ~4x below
# what materializing 10^6 Jobs plus the completion/slowdown vectors
# would need, so an accidental collect() trips it immediately.
if [[ "$BUILD" == ok ]]; then
  STREAM=fail
  STREAM_DIR=$(mktemp -d)
  STREAM_TRACE="$STREAM_DIR/ircache_1m.psbt"
  STREAM_RSS_KB=300000
  if ./target/release/psbs gen-trace --stats ircache --njobs 1000000 \
       --format bin --seed 7 --out "$STREAM_TRACE"; then
    if command -v /usr/bin/time >/dev/null 2>&1 &&
       /usr/bin/time -v true >/dev/null 2>&1; then
      # GNU time reports Maximum resident set size in KB.
      if /usr/bin/time -v -o "$STREAM_DIR/time.txt" \
           ./target/release/psbs replay --trace "$STREAM_TRACE" \
           --format bin --policy psbs; then
        RSS_KB=$(awk '/Maximum resident set size/ {print $NF}' "$STREAM_DIR/time.txt")
        echo "tier1: streaming-smoke MaxRSS ${RSS_KB:-?} KB (ceiling $STREAM_RSS_KB)"
        if [[ -n "${RSS_KB:-}" && "$RSS_KB" -lt "$STREAM_RSS_KB" ]]; then STREAM=ok; fi
      fi
    else
      # No GNU time: enforce the ceiling as an address-space ulimit in
      # a subshell — the replay dies (allocation failure) if it tries
      # to materialize the workload.  The -v limit bounds virtual
      # memory, so give it extra slack over the RSS ceiling.
      echo "tier1: /usr/bin/time -v unavailable; using ulimit -v fallback"
      if ( ulimit -v $((STREAM_RSS_KB * 4)) 2>/dev/null || true
           exec ./target/release/psbs replay --trace "$STREAM_TRACE" \
             --format bin --policy psbs ); then
        STREAM=ok
      fi
    fi
  fi
  rm -rf "$STREAM_DIR"
fi

# Serve smoke (BLOCKING, runs even with --no-bench): pipe a 10k-row
# CSV trace plus a final `drain` verb through one live
# `psbs serve --stdin` session in free-run mode and require every row
# to come back as a `done` line with a clean `bye` summary — the serve
# frontend (reader thread, bounded ingress queue, live clock) is
# exercised end-to-end on every verify, not just in-process tests.
if [[ "$BUILD" == ok ]]; then
  SERVE=fail
  SERVE_DIR=$(mktemp -d)
  SERVE_TRACE="$SERVE_DIR/trace.csv"
  SERVE_OUT="$SERVE_DIR/serve.out"
  if ./target/release/psbs gen-trace --stats facebook --njobs 10000 \
       --format csv --seed 11 --out "$SERVE_TRACE"; then
    if { cat "$SERVE_TRACE"; echo drain; } | \
         ./target/release/psbs serve --stdin --speedup inf > "$SERVE_OUT"; then
      DONE_N=$(grep -c '^done ' "$SERVE_OUT")
      ERR_N=$(grep -c '^err ' "$SERVE_OUT")
      echo "tier1: serve-smoke $DONE_N done lines, $ERR_N err lines (want 10000, 0)"
      if [[ "$DONE_N" -eq 10000 && "$ERR_N" -eq 0 ]] &&
         grep -q '^bye delivered=10000 completed=10000 killed=0 aborted=false$' "$SERVE_OUT"; then
        SERVE=ok
      fi
    fi
  fi
  rm -rf "$SERVE_DIR"
fi

if [[ "${1:-}" != "--no-bench" && "$BUILD" == ok ]]; then
  # BENCH_MS bounds each benchmark's measurement budget; the filters
  # restrict the run to the per-event scheduler numbers (psbs vs
  # fsp-naive) and the sweep-executor scaling grid (per-cell vs
  # planner) — which includes sweep/trace_parse/rows50k, so the smoke's
  # BENCH_sweeps.json carries the trace_parse_throughput derived sample
  # and trace ingestion perf rides the bench-compare step from day one.
  # The smoke writes into its own directory: a filtered run contains
  # only the filtered samples and must not clobber full reports from an
  # unfiltered `cargo bench` (those are the ones tracked across PRs).
  BENCH=fail
  mkdir -p bench-smoke
  # The psbs_ops late_set/ filter keeps the shared late-set engine
  # (sched/late_set.rs) on the perf radar from day one: the smoke's
  # BENCH_psbs_ops.json carries the late_set/* samples and the derived
  # late_set_*_scaling keys (informational in bench-compare).
  # schedulers gets the comma filter (any-substring match) so ONE
  # invocation covers the per-event probes AND the batch/soa families:
  # a filtered run rewrites BENCH_sched.json whole, so splitting this
  # into two runs would drop the first run's gated derived key
  # (batch_event_speedup) from the report bench-compare reads.
  if BENCH_OUT_DIR=bench-smoke BENCH_MS=150 cargo bench --bench schedulers -- event/,batch/,soa/ &&
     BENCH_OUT_DIR=bench-smoke BENCH_MS=150 cargo bench --bench psbs_ops -- late_set/ &&
     BENCH_OUT_DIR=bench-smoke BENCH_MS=150 cargo bench --bench figures -- sweep/; then
    BENCH=ok
    echo "--- bench-smoke/BENCH_sched.json derived (batch_event_speedup + soa_event_ns) ---"
    grep -o '"derived": {[^}]*}' bench-smoke/BENCH_sched.json || true
    echo "--- bench-smoke/BENCH_sweeps.json derived (speedups + trace_parse_throughput) ---"
    grep -o '"derived": {[^}]*}' bench-smoke/BENCH_sweeps.json || true
    echo "--- bench-smoke/BENCH_psbs_ops.json derived (late_set_* scaling) ---"
    grep -o '"derived": {[^}]*}' bench-smoke/BENCH_psbs_ops.json || true
  fi
fi

PASS=true
for gate in "$BUILD" "$TEST" "$BENCH" "$STREAM" "$SERVE"; do
  [[ "$gate" == fail ]] && PASS=false
done

echo "{\"tier1\": \"$([[ $PASS == true ]] && echo pass || echo fail)\", \"toolchain\": \"present\", \"build\": \"$BUILD\", \"test\": \"$TEST\", \"fmt\": \"$FMT\", \"clippy\": \"$CLIPPY\", \"bench\": \"$BENCH\", \"streaming_smoke\": \"$STREAM\", \"serve_smoke\": \"$SERVE\"}"
[[ "$PASS" == true ]]
