#!/usr/bin/env bash
# Tier-1 verify in one command: release build + full test suite + a
# short (~10 s) bench smoke that refreshes the machine-readable
# BENCH_*.json perf reports (schema: rust/benches/README.md).
#
# Usage:
#   scripts/tier1.sh             # build + test + bench smoke
#   scripts/tier1.sh --no-bench  # build + test only
set -euo pipefail
cd "$(dirname "$0")/.."

cargo build --release
cargo test -q

if [[ "${1:-}" != "--no-bench" ]]; then
  # BENCH_MS bounds each benchmark's measurement budget; the filters
  # restrict the run to the per-event scheduler numbers (psbs vs
  # fsp-naive) and the parallel-sweep scaling grid.  The smoke writes
  # into its own directory: a filtered run contains only the filtered
  # samples and must not clobber full reports from an unfiltered
  # `cargo bench` (those are the ones tracked across PRs).
  mkdir -p bench-smoke
  BENCH_OUT_DIR=bench-smoke BENCH_MS=150 cargo bench --bench schedulers -- event/
  BENCH_OUT_DIR=bench-smoke BENCH_MS=150 cargo bench --bench figures -- sweep/
  echo "--- bench-smoke/BENCH_sweeps.json derived speedups ---"
  grep -o '"derived": {[^}]*}' bench-smoke/BENCH_sweeps.json || true
fi

echo "tier1 OK"
