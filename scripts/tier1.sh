#!/usr/bin/env bash
# Tier-1 verify in one command: release build + full test suite +
# format/lint gates + a short (~10 s) bench smoke that refreshes the
# machine-readable BENCH_*.json perf reports (schema:
# rust/benches/README.md).
#
# fmt and clippy are skipped gracefully when the toolchain lacks the
# component (offline containers often ship bare rustc/cargo) and are
# ADVISORY: their status lands in the JSON summary but does not flip
# the tier-1 exit code (the repo has never been auto-formatted — make
# them blocking once a toolchain-equipped environment has run
# `cargo fmt` / fixed the first clippy pass).  Build, test and bench
# failures are fatal.  The last line is a one-line JSON pass/fail
# summary for machines.
#
# Usage:
#   scripts/tier1.sh             # build + test + fmt + clippy + bench smoke
#   scripts/tier1.sh --no-bench  # skip the bench smoke
set -uo pipefail
cd "$(dirname "$0")/.."

BUILD=fail TEST=skipped FMT=skipped CLIPPY=skipped BENCH=skipped

if cargo build --release; then BUILD=ok; fi

if [[ "$BUILD" == ok ]]; then
  TEST=fail
  if cargo test -q; then TEST=ok; fi
fi

# Format gate: only when rustfmt is installed for this toolchain.
if cargo fmt --version >/dev/null 2>&1; then
  FMT=fail
  if cargo fmt --check; then FMT=ok; fi
else
  echo "tier1: rustfmt unavailable; skipping fmt gate"
fi

# Lint gate: only when clippy is installed; warnings are errors.
if cargo clippy --version >/dev/null 2>&1; then
  CLIPPY=fail
  if cargo clippy --all-targets -- -D warnings; then CLIPPY=ok; fi
else
  echo "tier1: clippy unavailable; skipping lint gate"
fi

if [[ "${1:-}" != "--no-bench" && "$BUILD" == ok ]]; then
  # BENCH_MS bounds each benchmark's measurement budget; the filters
  # restrict the run to the per-event scheduler numbers (psbs vs
  # fsp-naive) and the sweep-executor scaling grid (per-cell vs
  # planner).  The smoke writes into its own directory: a filtered run
  # contains only the filtered samples and must not clobber full
  # reports from an unfiltered `cargo bench` (those are the ones
  # tracked across PRs).
  BENCH=fail
  mkdir -p bench-smoke
  if BENCH_OUT_DIR=bench-smoke BENCH_MS=150 cargo bench --bench schedulers -- event/ &&
     BENCH_OUT_DIR=bench-smoke BENCH_MS=150 cargo bench --bench figures -- sweep/; then
    BENCH=ok
    echo "--- bench-smoke/BENCH_sweeps.json derived speedups ---"
    grep -o '"derived": {[^}]*}' bench-smoke/BENCH_sweeps.json || true
  fi
fi

PASS=true
for gate in "$BUILD" "$TEST" "$BENCH"; do
  [[ "$gate" == fail ]] && PASS=false
done

echo "{\"tier1\": \"$([[ $PASS == true ]] && echo pass || echo fail)\", \"build\": \"$BUILD\", \"test\": \"$TEST\", \"fmt\": \"$FMT\", \"clippy\": \"$CLIPPY\", \"bench\": \"$BENCH\"}"
[[ "$PASS" == true ]]
