"""Layer-2 graph shape/semantics tests + AOT text emission checks."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels import binning, ecdf, ref


def _thr():
    return jnp.asarray(np.logspace(0, 3, ecdf.NUM_THRESHOLDS), jnp.float32)


def test_workload_graph_shapes_and_semantics():
    rng = np.random.default_rng(0)
    n = model.BATCH
    u = [jnp.asarray(rng.random(n), jnp.float32) for _ in range(3)]
    params = jnp.asarray([1.0, 2.0, 0.5, 0.0], jnp.float32)
    samples, mult = model.workload_graph(*u, params)
    assert samples.shape == (n,) and mult.shape == (n,)
    np.testing.assert_allclose(samples, ref.weibull_icdf(u[0], params),
                               rtol=1e-5)
    np.testing.assert_allclose(mult, ref.lognormal_mult(u[1], u[2], params),
                               rtol=1e-5)


def test_workload_graph_pareto_selector():
    """params[3] = 1 switches the size distribution to Pareto."""
    rng = np.random.default_rng(2)
    n = 4096
    u = [jnp.asarray(rng.random(n), jnp.float32) for _ in range(3)]
    params = jnp.asarray([2.0, 0.5, 0.5, 1.0], jnp.float32)
    samples, _ = model.workload_graph(*u, params)
    np.testing.assert_allclose(samples, ref.pareto_icdf(u[0], params),
                               rtol=1e-5)
    # Pareto samples are bounded below by x_m; Weibull(2, .5) is not.
    assert float(jnp.min(samples)) >= 0.5 * (1 - 1e-6)


def test_analytics_graph_mst_and_chunk_linearity():
    """Splitting a population into chunks must aggregate exactly."""
    rng = np.random.default_rng(1)
    n = model.BATCH
    sizes = jnp.asarray(rng.random(n).astype(np.float32) + 0.01)
    soj = sizes * 3.0
    mask = jnp.asarray((rng.random(n) > 0.5).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, binning.NUM_BINS, n), jnp.int32)
    thr = _thr()

    full = model.analytics_graph(sizes, soj, mask, idx, thr)

    # Same population, but masked as two disjoint halves.
    m1 = mask * jnp.asarray(([1.0, 0.0] * (n // 2)), jnp.float32)
    m2 = mask - m1
    h1 = model.analytics_graph(sizes, soj, m1, idx, thr)
    h2 = model.analytics_graph(sizes, soj, m2, idx, thr)
    for k in (1, 2, 3, 4, 5):  # all aggregate outputs are mask-linear
        np.testing.assert_allclose(np.asarray(h1[k]) + np.asarray(h2[k]),
                                   np.asarray(full[k]), rtol=1e-4, atol=1e-3)

    # MST from the aggregates equals the masked mean.
    mst = float(full[4][0] / full[5][0])
    want = float(jnp.sum(soj * mask) / jnp.sum(mask))
    assert abs(mst - want) < 1e-4 * want


def test_aot_emits_parseable_hlo_text():
    batch = 4096  # one elementwise block: keep the test fast
    for text, name in ((aot.lower_workload(batch), "workload_graph"),
                       (aot.lower_analytics(batch), "analytics_graph")):
        assert text.startswith("HloModule")
        assert name in text.splitlines()[0]
        assert "ENTRY" in text
        assert f"f32[{batch}]" in text


def test_manifest_roundtrip(tmp_path):
    p = tmp_path / "manifest.txt"
    aot.write_manifest(str(p), 4096)
    kv = dict(line.split("=", 1) for line in p.read_text().splitlines())
    assert kv["batch"] == "4096"
    assert int(kv["num_bins"]) == binning.NUM_BINS
    assert int(kv["num_thresholds"]) == ecdf.NUM_THRESHOLDS
    assert kv["workload"] == "workload.hlo.txt"


def test_specs_match_graph_signature():
    lowered = jax.jit(model.workload_graph).lower(*model.workload_specs(4096))
    assert lowered is not None
    lowered = jax.jit(model.analytics_graph).lower(*model.analytics_specs(4096))
    assert lowered is not None
