# pytest: kernel vs ref allclose — the CORE correctness signal.
"""Pallas kernels vs the pure-jnp oracle (`compile.kernels.ref`).

Hypothesis sweeps block sizes, array lengths and parameter ranges; every
kernel must match its oracle to f32 tolerance.  Agreement here validates
the kernels' block decomposition and cross-grid accumulation, not just
the elementwise math.
"""

import math

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import binning, ecdf, lognormal, pareto, ref, weibull

# Kernel blocks under test: lane-aligned and the production default.
BLOCKS = st.sampled_from([128, 256, 1024])
# Number of blocks in the array (exercises grid accumulation).
NBLOCKS = st.integers(min_value=1, max_value=5)

HYPO = dict(max_examples=25, deadline=None)


def _uniforms(rng, n):
    return jnp.asarray(rng.random(n), jnp.float32)


def _params(shape=0.25, scale=1.0, sigma=0.5):
    return jnp.asarray([shape, scale, sigma, 0.0], jnp.float32)


# ---------------------------------------------------------------- weibull

@settings(**HYPO)
@given(block=BLOCKS, nblocks=NBLOCKS,
       shape=st.floats(0.125, 4.0), scale=st.floats(1e-3, 1e3),
       seed=st.integers(0, 2**32 - 1))
def test_weibull_matches_ref(block, nblocks, shape, scale, seed):
    rng = np.random.default_rng(seed)
    u = _uniforms(rng, block * nblocks)
    params = _params(shape=shape, scale=scale)
    got = weibull.weibull_icdf(u, params, block=block)
    want = ref.weibull_icdf(u, params)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=0)


def test_weibull_rejects_ragged():
    with pytest.raises(ValueError):
        weibull.weibull_icdf(jnp.zeros(100, jnp.float32), _params(), block=128)


def test_weibull_exponential_mean():
    # shape=1, scale=1 is Exp(1): sample mean ~= 1.
    rng = np.random.default_rng(7)
    u = _uniforms(rng, 1 << 16)
    s = weibull.weibull_icdf(u, _params(shape=1.0, scale=1.0))
    assert abs(float(jnp.mean(s)) - 1.0) < 0.02


def test_weibull_extreme_uniforms_finite():
    # u == 0 and u == 1 must clamp, not produce inf/nan.
    u = jnp.asarray([0.0, 1.0, 0.5, np.nextafter(1.0, 0.0)], jnp.float32)
    u = jnp.tile(u, 32)  # one 128-block
    s = weibull.weibull_icdf(u, _params(shape=0.125), block=128)
    assert bool(jnp.all(jnp.isfinite(s)))


# ----------------------------------------------------------------- pareto

@settings(**HYPO)
@given(block=BLOCKS, nblocks=NBLOCKS,
       alpha=st.floats(0.5, 4.0), xm=st.floats(1e-3, 1e3),
       seed=st.integers(0, 2**32 - 1))
def test_pareto_matches_ref(block, nblocks, alpha, xm, seed):
    rng = np.random.default_rng(seed)
    u = _uniforms(rng, block * nblocks)
    params = _params(shape=alpha, scale=xm)
    got = pareto.pareto_icdf(u, params, block=block)
    want = ref.pareto_icdf(u, params)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=0)


def test_pareto_samples_above_xm():
    rng = np.random.default_rng(13)
    u = _uniforms(rng, 1024)
    s = pareto.pareto_icdf(u, _params(shape=2.0, scale=0.5), block=1024)
    assert bool(jnp.all(s >= 0.5 * (1 - 1e-6)))


def test_pareto_unit_mean_alpha2():
    # Pareto(xm = 0.5, alpha = 2) has mean alpha*xm/(alpha-1) = 1.
    rng = np.random.default_rng(17)
    u = _uniforms(rng, 1 << 17)
    s = pareto.pareto_icdf(u, _params(shape=2.0, scale=0.5), block=1024)
    assert abs(float(jnp.mean(s)) - 1.0) < 0.05


def test_pareto_rejects_ragged():
    with pytest.raises(ValueError):
        pareto.pareto_icdf(jnp.zeros(100, jnp.float32), _params(), block=128)


# -------------------------------------------------------------- lognormal

@settings(**HYPO)
@given(block=BLOCKS, nblocks=NBLOCKS, sigma=st.floats(0.0, 4.0),
       seed=st.integers(0, 2**32 - 1))
def test_lognormal_matches_ref(block, nblocks, sigma, seed):
    rng = np.random.default_rng(seed)
    n = block * nblocks
    u1, u2 = _uniforms(rng, n), _uniforms(rng, n)
    params = _params(sigma=sigma)
    got = lognormal.lognormal_mult(u1, u2, params, block=block)
    want = ref.lognormal_mult(u1, u2, params)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=0)


def test_lognormal_sigma_zero_is_one():
    rng = np.random.default_rng(3)
    u1, u2 = _uniforms(rng, 256), _uniforms(rng, 256)
    m = lognormal.lognormal_mult(u1, u2, _params(sigma=0.0), block=128)
    np.testing.assert_allclose(m, jnp.ones(256), rtol=0)


def test_lognormal_median_near_one():
    # LogNormal(0, sigma) has median 1 for any sigma (paper §6.3:
    # under- and over-estimation equally likely).
    rng = np.random.default_rng(11)
    n = 1 << 16
    u1, u2 = _uniforms(rng, n), _uniforms(rng, n)
    m = lognormal.lognormal_mult(u1, u2, _params(sigma=2.0))
    med = float(jnp.median(m))
    assert 0.95 < med < 1.05


# ---------------------------------------------------------------- binning

def _jobs(rng, n):
    sizes = jnp.asarray(rng.random(n).astype(np.float32) * 10 + 1e-3)
    soj = sizes * jnp.asarray(1.0 + 20 * rng.random(n), jnp.float32)
    mask = jnp.asarray((rng.random(n) > 0.15).astype(np.float32))
    # Includes the out-of-range padding index NUM_BINS.
    idx = jnp.asarray(rng.integers(0, binning.NUM_BINS + 1, n), jnp.int32)
    return sizes, soj, mask, idx


@settings(**HYPO)
@given(block=BLOCKS, nblocks=NBLOCKS, seed=st.integers(0, 2**32 - 1))
def test_binning_matches_ref(block, nblocks, seed):
    rng = np.random.default_rng(seed)
    sizes, soj, mask, idx = _jobs(rng, block * nblocks)
    slow, sums, counts = binning.slowdown_bins(soj, sizes, mask, idx,
                                               block=block)
    slow_r, sums_r, counts_r = ref.slowdown_bins(soj, sizes, mask, idx)
    np.testing.assert_allclose(slow, slow_r, rtol=1e-5)
    np.testing.assert_allclose(sums, sums_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(counts, counts_r, rtol=0)


def test_binning_counts_are_exact_and_conserved():
    rng = np.random.default_rng(5)
    sizes, soj, mask, idx = _jobs(rng, 2048)
    # All in-range so every valid job lands in exactly one class.
    idx = jnp.asarray(rng.integers(0, binning.NUM_BINS, 2048), jnp.int32)
    _, _, counts = binning.slowdown_bins(soj, sizes, mask, idx, block=256)
    assert float(jnp.sum(counts)) == float(jnp.sum(mask))


def test_binning_padding_contributes_nothing():
    n = 512
    sizes = jnp.zeros(n, jnp.float32)  # padding: size 0
    soj = jnp.ones(n, jnp.float32) * 1e6
    mask = jnp.zeros(n, jnp.float32)
    idx = jnp.full((n,), binning.NUM_BINS, jnp.int32)
    slow, sums, counts = binning.slowdown_bins(soj, sizes, mask, idx,
                                               block=128)
    assert float(jnp.sum(jnp.abs(slow))) == 0.0
    assert float(jnp.sum(sums)) == 0.0 and float(jnp.sum(counts)) == 0.0


# ------------------------------------------------------------------- ecdf

@settings(**HYPO)
@given(block=BLOCKS, nblocks=NBLOCKS, seed=st.integers(0, 2**32 - 1))
def test_ecdf_matches_ref(block, nblocks, seed):
    rng = np.random.default_rng(seed)
    n = block * nblocks
    slow = jnp.asarray(1.0 + 200 * rng.random(n), jnp.float32)
    mask = jnp.asarray((rng.random(n) > 0.2).astype(np.float32))
    thr = jnp.asarray(np.logspace(0, math.log10(300), ecdf.NUM_THRESHOLDS),
                      jnp.float32)
    got = ecdf.ecdf_counts(slow, mask, thr, block=block)
    want = ref.ecdf_counts(slow, mask, thr)
    np.testing.assert_allclose(got, want, rtol=0)


def test_ecdf_monotone_and_saturates():
    rng = np.random.default_rng(9)
    slow = jnp.asarray(1.0 + 10 * rng.random(1024), jnp.float32)
    mask = jnp.ones(1024, jnp.float32)
    thr = jnp.asarray(np.linspace(0.0, 100.0, ecdf.NUM_THRESHOLDS),
                      jnp.float32)
    counts = np.asarray(ecdf.ecdf_counts(slow, mask, thr, block=256))
    assert (np.diff(counts) >= 0).all()
    assert counts[-1] == 1024.0  # all slowdowns <= 100
