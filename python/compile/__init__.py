"""Build-time Python for the PSBS reproduction.

This package exists only on the compile path: :mod:`compile.aot` lowers
the Layer-2 JAX graphs (which call the Layer-1 Pallas kernels) to HLO
text artifacts that the rust coordinator loads via PJRT.  Nothing here
is imported at runtime.
"""
