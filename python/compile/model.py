"""Layer-2 JAX graphs for the PSBS evaluation pipeline.

Two jitted computations, AOT-lowered once by :mod:`compile.aot` to HLO
text and executed from the rust coordinator through the PJRT C API:

* :func:`workload_graph` — synthetic-workload synthesis: Weibull
  inverse-CDF samples (job sizes *or* inter-arrival gaps, depending on
  the parameter vector) plus log-normal size-estimation-error
  multipliers (paper §6.3, Table 1).
* :func:`analytics_graph` — the metric pipeline over one batch of
  completed jobs: per-job slowdown, mean-conditional-slowdown class
  aggregation (Fig. 7) and slowdown-ECDF threshold counts (Figs. 4, 8),
  plus the sojourn-time sum/count for MST.

Both graphs call the Layer-1 Pallas kernels so that the kernels lower
into the same HLO module.  Shapes are fixed at AOT time (``BATCH``);
the rust side chunks and masks larger job populations and aggregates
the per-chunk partials (all outputs here are linear in the mask, so
chunk aggregation is exact).

PARAMS_LAYOUT documents the runtime parameter vector shared by the
workload kernels:

    params[0] = weibull shape / pareto alpha (Table 1 `shape`, Fig. 10)
    params[1] = weibull scale / pareto x_m   (rust precomputes
                                      1/Gamma(1+1/shape) for unit mean,
                                      or the load-matched arrival scale)
    params[2] = sigma                (log-normal error parameter)
    params[3] = size distribution    (0 = Weibull, 1 = Pareto — Fig. 10)
"""

import jax.numpy as jnp

from .kernels import binning, ecdf, lognormal, pareto, weibull

# AOT batch: one chunk of jobs per execution.
BATCH = 32768

# Runtime parameter vector length (see PARAMS_LAYOUT in the docstring).
NUM_PARAMS = 4

PARAMS_LAYOUT = ("shape_or_alpha", "scale_or_xm", "sigma", "dist_select")


def workload_graph(u_size, u_a, u_b, params):
    """Synthesize one batch of Weibull samples + error multipliers.

    Args:
      u_size: f32[BATCH] uniforms driving the Weibull inverse CDF.
      u_a:    f32[BATCH] uniforms (Box-Muller radius).
      u_b:    f32[BATCH] uniforms (Box-Muller angle).
      params: f32[NUM_PARAMS] runtime parameters (PARAMS_LAYOUT).

    Returns:
      (samples f32[BATCH], err_mult f32[BATCH]) — job sizes (or gaps)
      and the multiplicative estimation errors exp(sigma * z).

    ``params[3]`` selects the size distribution (0 = Weibull for the
    Table-1 sweeps, 1 = Pareto for Fig. 10).  ``lax.cond`` keeps the
    artifact monolithic (one compiled module for every experiment)
    while executing only the selected transform at runtime — XLA lowers
    it to a conditional, not a compute-both-and-select
    (EXPERIMENTS.md §Perf records the L2 iteration).
    """
    import jax.lax as lax

    samples = lax.cond(
        params[3] > 0.5,
        lambda u: pareto.pareto_icdf(u, params),
        lambda u: weibull.weibull_icdf(u, params),
        u_size,
    )
    err_mult = lognormal.lognormal_mult(u_a, u_b, params)
    return samples, err_mult


def analytics_graph(sizes, sojourns, mask, bin_idx, thresholds):
    """Metric pipeline over one batch of completed jobs.

    Args:
      sizes:      f32[BATCH] true job sizes (0 padding).
      sojourns:   f32[BATCH] per-job sojourn times.
      mask:       f32[BATCH] 1.0 valid / 0.0 padding.
      bin_idx:    i32[BATCH] equal-count size-class index
                  (binning.NUM_BINS for padding).
      thresholds: f32[ecdf.NUM_THRESHOLDS] slowdown ECDF grid.

    Returns:
      (slowdowns f32[BATCH],
       bin_sums f32[NUM_BINS], bin_counts f32[NUM_BINS],
       ecdf_counts f32[NUM_THRESHOLDS],
       sojourn_sum f32[1], count f32[1])
    """
    slow, bin_sums, bin_counts = binning.slowdown_bins(
        sojourns, sizes, mask, bin_idx)
    counts = ecdf.ecdf_counts(slow, mask, thresholds)
    sojourn_sum = jnp.sum(sojourns * mask, keepdims=True)
    count = jnp.sum(mask, keepdims=True)
    return slow, bin_sums, bin_counts, counts, sojourn_sum, count


def workload_specs(batch=BATCH):
    """ShapeDtypeStructs matching :func:`workload_graph`."""
    import jax

    f32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)  # noqa: E731
    return (f32(batch), f32(batch), f32(batch), f32(NUM_PARAMS))


def analytics_specs(batch=BATCH):
    """ShapeDtypeStructs matching :func:`analytics_graph`."""
    import jax

    f32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)  # noqa: E731
    i32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.int32)  # noqa: E731
    return (f32(batch), f32(batch), f32(batch), i32(batch),
            f32(ecdf.NUM_THRESHOLDS))
