"""AOT-lower the Layer-2 graphs to HLO text for the rust runtime.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the published
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/README.md and gen_hlo.py there.

Usage (from ``make artifacts``):

    cd python && python -m compile.aot --out-dir ../artifacts

Produces:
    artifacts/workload.hlo.txt    (workload_graph)
    artifacts/analytics.hlo.txt   (analytics_graph)
    artifacts/manifest.txt        (batch size + shapes, parsed by rust)
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model
from .kernels import binning, ecdf


def to_hlo_text(lowered) -> str:
    """Convert a jax Lowered to XLA HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_workload(batch: int) -> str:
    lowered = jax.jit(model.workload_graph).lower(*model.workload_specs(batch))
    return to_hlo_text(lowered)


def lower_analytics(batch: int) -> str:
    lowered = jax.jit(model.analytics_graph).lower(*model.analytics_specs(batch))
    return to_hlo_text(lowered)


def write_manifest(path: str, batch: int) -> None:
    """Key=value manifest the rust runtime parses at load time."""
    lines = [
        f"batch={batch}",
        f"num_params={model.NUM_PARAMS}",
        f"num_bins={binning.NUM_BINS}",
        f"num_thresholds={ecdf.NUM_THRESHOLDS}",
        "workload=workload.hlo.txt",
        "analytics=analytics.hlo.txt",
    ]
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts",
                        help="directory for the HLO artifacts")
    parser.add_argument("--batch", type=int, default=model.BATCH,
                        help="AOT batch size (jobs per execution)")
    args = parser.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    for name, text in (
        ("workload.hlo.txt", lower_workload(args.batch)),
        ("analytics.hlo.txt", lower_analytics(args.batch)),
    ):
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text):>9} chars to {path}")
    write_manifest(os.path.join(args.out_dir, "manifest.txt"), args.batch)
    print(f"wrote manifest to {os.path.join(args.out_dir, 'manifest.txt')}")


if __name__ == "__main__":
    main()
