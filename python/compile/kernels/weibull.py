"""Inverse-CDF Weibull sampling as a Pallas kernel.

The synthetic workloads of the paper (Table 1) draw job sizes and
inter-arrival gaps from Weibull distributions whose ``shape`` parameter
interpolates between heavy-tailed (shape < 1), exponential (shape = 1)
and light-tailed (shape > 1) regimes.  The rust coordinator supplies a
vector of uniforms ``u ~ U(0,1)`` (from its own deterministic xoshiro
stream) and the distribution parameters at *runtime*; the transform

    s = scale * (-log(1 - u)) ** (1 / shape)

runs inside the AOT-compiled artifact, so one compiled module covers
the whole Table-1 parameter sweep.

TPU notes (DESIGN.md §Hardware-Adaptation): the transform is purely
elementwise, so the kernel is VPU work tiled in ``(BLOCK,)`` chunks
(``BLOCK`` a multiple of 8*128 = 1024 for lane alignment).  Per-step
VMEM footprint is 2 * BLOCK * 4 B  (in + out) — 8 KiB at the default
block, leaving the full VMEM budget for double buffering.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default element block: 8 sublanes * 128 lanes.
BLOCK = 4096

# Uniforms are clamped into [EPS, 1 - EPS_HI] so that log(1-u) is finite
# and nonzero; EPS_HI is one f32 ulp below 1.
EPS = 1e-7


def _weibull_kernel(u_ref, params_ref, out_ref):
    """One grid step: out = scale * (-log1p(-u)) ** (1/shape)."""
    shape = params_ref[0]
    scale = params_ref[1]
    u = jnp.clip(u_ref[...], EPS, 1.0 - EPS)
    # (-log(1-u))^(1/k) computed in log-space for numerical range:
    # exp(log(-log1p(-u)) / k).  -log1p(-u) > 0 after clamping.
    neg_log = -jnp.log1p(-u)
    out_ref[...] = scale * jnp.exp(jnp.log(neg_log) / shape)


@functools.partial(jax.jit, static_argnames=("block",))
def weibull_icdf(u, params, *, block=BLOCK):
    """Map uniforms ``u`` to Weibull(shape, scale) samples.

    Args:
      u: f32[N] uniforms in (0, 1); N must be a multiple of ``block``
        (the rust caller pads to the AOT batch).
      params: f32[PARAMS] runtime parameter vector; ``params[0]`` is the
        Weibull shape, ``params[1]`` the scale.  Extra slots are shared
        with the other workload kernels (see model.PARAMS_LAYOUT).
      block: element block per grid step.

    Returns:
      f32[N] samples.
    """
    n = u.shape[0]
    if n % block != 0:
        raise ValueError(f"N={n} must be a multiple of block={block}")
    return pl.pallas_call(
        _weibull_kernel,
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec(params.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), u.dtype),
        interpret=True,
    )(u, params)
