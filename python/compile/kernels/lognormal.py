"""Log-normal size-estimation error multipliers as a Pallas kernel.

The paper's error model (§6.3, Eq. 1): a job of true size ``s`` is
estimated as ``s_hat = s * X`` with ``X ~ LogNormal(0, sigma^2)`` —
multiplicative error, symmetric in log-space, no bound.  The kernel
fuses the Box-Muller transform (two uniforms -> one standard normal)
with the exponential scaling:

    z    = sqrt(-2 log u1) * cos(2 pi u2)
    mult = exp(sigma * z)

``sigma`` arrives at runtime through the shared parameter vector so the
single AOT artifact covers the entire sigma sweep (0.125 .. 4).

TPU notes: elementwise VPU work; 3 * BLOCK * 4 B VMEM per step.  The
transcendental chain (log, sqrt, cos, exp) is exactly the kind of work
that would bottleneck a scalar host loop during large sweeps, which is
why it lives in the artifact rather than in the rust coordinator.
"""

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .weibull import BLOCK, EPS

TWO_PI = 2.0 * math.pi


def _lognormal_kernel(u1_ref, u2_ref, params_ref, out_ref):
    """One grid step of fused Box-Muller + exp(sigma * z)."""
    sigma = params_ref[2]
    u1 = jnp.clip(u1_ref[...], EPS, 1.0 - EPS)
    u2 = u2_ref[...]
    z = jnp.sqrt(-2.0 * jnp.log(u1)) * jnp.cos(TWO_PI * u2)
    out_ref[...] = jnp.exp(sigma * z)


@functools.partial(jax.jit, static_argnames=("block",))
def lognormal_mult(u1, u2, params, *, block=BLOCK):
    """Map uniform pairs to LogNormal(0, sigma^2) multipliers.

    Args:
      u1: f32[N] uniforms in (0, 1) — radius component.
      u2: f32[N] uniforms in [0, 1) — angle component.
      params: f32[PARAMS] runtime parameters; ``params[2]`` is sigma.
      block: element block per grid step; N % block == 0.

    Returns:
      f32[N] multiplicative error factors ``exp(sigma * z)``.
    """
    n = u1.shape[0]
    if n % block != 0:
        raise ValueError(f"N={n} must be a multiple of block={block}")
    if u2.shape != u1.shape:
        raise ValueError("u1 and u2 must have the same shape")
    return pl.pallas_call(
        _lognormal_kernel,
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec(params.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), u1.dtype),
        interpret=True,
    )(u1, u2, params)
