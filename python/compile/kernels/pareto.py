"""Inverse-CDF Pareto sampling as a Pallas kernel (paper Fig. 10).

The paper's §7.7 "Pareto Job Size Distribution" experiments use
Pareto(x_m, alpha) with alpha in {1, 2}.  Same AOT strategy as the
Weibull kernel: uniforms come from the rust coordinator, distribution
parameters arrive at runtime, and the transform

    s = x_m / (1 - u) ** (1 / alpha)

runs inside the compiled artifact.  Parameter-slot reuse (see
model.PARAMS_LAYOUT): ``params[0]`` is alpha, ``params[1]`` is x_m —
the same slots the Weibull kernel reads as (shape, scale), selected by
``params[3]`` in :func:`compile.model.workload_graph`.

TPU notes: elementwise VPU work, identical tiling to the Weibull
kernel — ``(BLOCK,)`` chunks, 8 KiB VMEM per step.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .weibull import BLOCK, EPS


def _pareto_kernel(u_ref, params_ref, out_ref):
    """One grid step: out = xm * (1 - u) ** (-1/alpha)."""
    alpha = params_ref[0]
    xm = params_ref[1]
    u = jnp.clip(u_ref[...], EPS, 1.0 - EPS)
    # (1-u)^(-1/alpha) = exp(-log1p(-u)/alpha); log1p(-u) < 0.
    out_ref[...] = xm * jnp.exp(-jnp.log1p(-u) / alpha)


@functools.partial(jax.jit, static_argnames=("block",))
def pareto_icdf(u, params, *, block=BLOCK):
    """Map uniforms ``u`` to Pareto(alpha, x_m) samples.

    Args:
      u: f32[N] uniforms in (0, 1); N must be a multiple of ``block``.
      params: f32[PARAMS] runtime parameters; ``params[0]`` = alpha,
        ``params[1]`` = x_m.
      block: element block per grid step.

    Returns:
      f32[N] samples (>= x_m).
    """
    n = u.shape[0]
    if n % block != 0:
        raise ValueError(f"N={n} must be a multiple of block={block}")
    return pl.pallas_call(
        _pareto_kernel,
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec(params.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), u.dtype),
        interpret=True,
    )(u, params)
