"""Fused slowdown + conditional-slowdown class aggregation kernel.

Fig. 7 of the paper plots *mean conditional slowdown*: jobs are sorted
by size and binned into equal-count classes; the figure shows, per
class, mean slowdown (sojourn / size).  Over a full sweep this is the
evaluation pipeline's hot loop — hundreds of runs x 10^4..10^5 jobs.

The rust coordinator assigns each job its class index (equal-count
binning needs a global sort, which rust does once per run); the kernel
then fuses, per tile of jobs:

    slowdown_j = sojourn_j / size_j            (masked)
    sums[c]   += sum_j slowdown_j * [idx_j == c]
    counts[c] += sum_j mask_j     * [idx_j == c]

**TPU mapping** (DESIGN.md §Hardware-Adaptation): on a GPU this
segmented reduction would be scatter-adds in shared memory; TPUs have
no efficient hot-path scatter, so the kernel materializes the per-tile
one-hot membership matrix ``(BLOCK x NUM_BINS)`` and reduces it with a
``(1 x BLOCK) . (BLOCK x NUM_BINS)`` product — MXU-shaped work with
``NUM_BINS = 128`` matching the lane width.  The two 128-wide
accumulators live in the output block, which is grid-invariant (index
map pins it to block 0), so it stays resident in VMEM across all grid
steps.  Per-step VMEM: 4 input tiles + one-hot (BLOCK*128*4 B = 512 KiB)
+ 2 accumulators — ~0.6 MiB, comfortably double-bufferable.

Out-of-range indices (the rust side tags padded jobs with
``idx = NUM_BINS``) fall outside the iota range and contribute nothing.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Binning materializes a (BLOCK x NUM_BINS) one-hot per step; keep the
# tile at 1024 x 128 x 4 B = 512 KiB so step working set stays L2-cache
# resident on CPU (and ~0.6 MiB VMEM on TPU) — larger tiles measured
# slower (EXPERIMENTS.md §Perf).
BLOCK = 1024

# Number of size classes. The paper uses 100; we allocate 128 (one MXU
# lane tile) and the rust side uses the first 100, keeping the rest 0.
NUM_BINS = 128

# Guard against division by zero for padded entries (size 0).
TINY = 1e-30


def _binning_kernel(soj_ref, size_ref, mask_ref, idx_ref,
                    slow_ref, sums_ref, counts_ref):
    step = pl.program_id(0)
    mask = mask_ref[...]
    size = jnp.maximum(size_ref[...], TINY)
    slow = soj_ref[...] / size * mask
    slow_ref[...] = slow

    # (BLOCK x NUM_BINS) one-hot membership, masked.
    classes = jax.lax.iota(jnp.int32, NUM_BINS)
    onehot = jnp.where(idx_ref[...][:, None] == classes[None, :],
                       mask[:, None], 0.0)

    @pl.when(step == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)

    # Segmented reduction as an MXU-shaped vector-matrix product.
    sums_ref[...] += jnp.dot(slow, onehot,
                             preferred_element_type=jnp.float32)
    counts_ref[...] += jnp.sum(onehot, axis=0)


@functools.partial(jax.jit, static_argnames=("block",))
def slowdown_bins(sojourns, sizes, mask, bin_idx, *, block=BLOCK):
    """Per-job slowdowns plus per-class slowdown sums and counts.

    Args:
      sojourns: f32[N] per-job sojourn times.
      sizes:    f32[N] per-job true sizes.
      mask:     f32[N] 1.0 for valid jobs, 0.0 for padding.
      bin_idx:  i32[N] size-class index in [0, NUM_BINS); padded jobs
                use NUM_BINS (contributes to nothing).
      block:    jobs per grid step; N % block == 0.

    Returns:
      (slowdowns f32[N], bin_sums f32[NUM_BINS], bin_counts f32[NUM_BINS]).
    """
    n = sojourns.shape[0]
    if n % block != 0:
        raise ValueError(f"N={n} must be a multiple of block={block}")
    return pl.pallas_call(
        _binning_kernel,
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((NUM_BINS,), lambda i: (0,)),
            pl.BlockSpec((NUM_BINS,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), sojourns.dtype),
            jax.ShapeDtypeStruct((NUM_BINS,), jnp.float32),
            jax.ShapeDtypeStruct((NUM_BINS,), jnp.float32),
        ],
        interpret=True,
    )(sojourns, sizes, mask, bin_idx)
