"""Layer-1 Pallas kernels for the PSBS evaluation pipeline.

Every kernel here is lowered with ``interpret=True``: the rust runtime
executes the resulting HLO on the CPU PJRT client, which cannot run
Mosaic custom-calls.  Real-TPU considerations (VMEM tiling, MXU-shaped
one-hot matmuls) are documented per kernel and in DESIGN.md
§Hardware-Adaptation.

Kernels:
  - :mod:`weibull`    — inverse-CDF Weibull sampling (job sizes, gaps)
  - :mod:`lognormal`  — Box-Muller + log-normal error multiplier
  - :mod:`binning`    — fused slowdown + equal-count class aggregation
  - :mod:`ecdf`       — slowdown ECDF threshold counts
  - :mod:`ref`        — pure-jnp oracle used by the pytest/hypothesis suite
"""

from . import binning, ecdf, lognormal, ref, weibull  # noqa: F401

__all__ = ["binning", "ecdf", "lognormal", "ref", "weibull"]
