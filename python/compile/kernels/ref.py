"""Pure-jnp oracle for every Pallas kernel.

These are the ground-truth implementations the pytest/hypothesis suite
compares the kernels against (``assert_allclose``).  They use plain
vectorized jnp ops — no pallas, no tiling — so any agreement between a
kernel and its oracle validates the kernel's block decomposition and
accumulation logic, not just the math.
"""

import math

import jax.numpy as jnp

from .binning import NUM_BINS, TINY
from .ecdf import NUM_THRESHOLDS
from .weibull import EPS

TWO_PI = 2.0 * math.pi


def weibull_icdf(u, params):
    """Oracle for :func:`kernels.weibull.weibull_icdf`."""
    shape, scale = params[0], params[1]
    u = jnp.clip(u, EPS, 1.0 - EPS)
    return scale * jnp.exp(jnp.log(-jnp.log1p(-u)) / shape)


def pareto_icdf(u, params):
    """Oracle for :func:`kernels.pareto.pareto_icdf`."""
    alpha, xm = params[0], params[1]
    u = jnp.clip(u, EPS, 1.0 - EPS)
    return xm * jnp.exp(-jnp.log1p(-u) / alpha)


def lognormal_mult(u1, u2, params):
    """Oracle for :func:`kernels.lognormal.lognormal_mult`."""
    sigma = params[2]
    u1 = jnp.clip(u1, EPS, 1.0 - EPS)
    z = jnp.sqrt(-2.0 * jnp.log(u1)) * jnp.cos(TWO_PI * u2)
    return jnp.exp(sigma * z)


def slowdown_bins(sojourns, sizes, mask, bin_idx):
    """Oracle for :func:`kernels.binning.slowdown_bins`."""
    slow = sojourns / jnp.maximum(sizes, TINY) * mask
    classes = jnp.arange(NUM_BINS, dtype=bin_idx.dtype)
    onehot = jnp.where(bin_idx[:, None] == classes[None, :],
                       mask[:, None], 0.0)
    sums = jnp.einsum("n,nc->c", slow, onehot)
    counts = jnp.sum(onehot, axis=0)
    return slow, sums, counts


def ecdf_counts(slowdowns, mask, thresholds):
    """Oracle for :func:`kernels.ecdf.ecdf_counts`."""
    assert thresholds.shape == (NUM_THRESHOLDS,)
    cmp = (slowdowns[:, None] <= thresholds[None, :]).astype(jnp.float32)
    return jnp.einsum("n,nk->k", mask, cmp)
