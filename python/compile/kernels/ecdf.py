"""Slowdown ECDF threshold counts as a Pallas kernel.

Figs. 4 and 8 of the paper plot the empirical CDF of per-job slowdown
(including a zoom on the worst 10%).  The kernel counts, for a fixed
grid of K thresholds, how many valid jobs have ``slowdown <= t_k``:

    counts[k] = sum_j mask_j * [slow_j <= t_k]

computed per tile as a masked ``(1 x BLOCK) . (BLOCK x K)`` reduction
over the comparison matrix — the same MXU-friendly recast of a
histogram as the binning kernel (no scatter on TPU).  ``K = 128``
matches the lane width; the threshold vector and the accumulator are
grid-invariant blocks resident in VMEM.

The thresholds are a runtime input: the rust side passes a log-spaced
grid (Fig. 8 spans slowdown 1 .. >100) and can re-execute the same
artifact with any other grid.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Same tile-size reasoning as binning.py: (BLOCK x K) comparison matrix
# per step, kept at 512 KiB.
BLOCK = 1024

# Number of ECDF thresholds (one lane tile).
NUM_THRESHOLDS = 128


def _ecdf_kernel(slow_ref, mask_ref, thr_ref, counts_ref):
    step = pl.program_id(0)
    cmp = (slow_ref[...][:, None] <= thr_ref[...][None, :]).astype(jnp.float32)

    @pl.when(step == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    counts_ref[...] += jnp.dot(mask_ref[...], cmp,
                               preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block",))
def ecdf_counts(slowdowns, mask, thresholds, *, block=BLOCK):
    """Count valid jobs with slowdown <= each threshold.

    Args:
      slowdowns:  f32[N] per-job slowdowns (0 for padding; masked out).
      mask:       f32[N] validity mask.
      thresholds: f32[NUM_THRESHOLDS] ECDF evaluation points.
      block:      jobs per grid step; N % block == 0.

    Returns:
      f32[NUM_THRESHOLDS] counts.
    """
    n = slowdowns.shape[0]
    if n % block != 0:
        raise ValueError(f"N={n} must be a multiple of block={block}")
    if thresholds.shape != (NUM_THRESHOLDS,):
        raise ValueError(f"thresholds must be ({NUM_THRESHOLDS},)")
    return pl.pallas_call(
        _ecdf_kernel,
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((NUM_THRESHOLDS,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((NUM_THRESHOLDS,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((NUM_THRESHOLDS,), jnp.float32),
        interpret=True,
    )(slowdowns, mask, thresholds)
